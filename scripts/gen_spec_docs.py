"""Generate docs/SPEC.md — the ExperimentSpec field reference — by
introspecting the spec dataclasses, their validators, and the registries.

    PYTHONPATH=src python scripts/gen_spec_docs.py [--check]

The document is fully derived: field names/types/defaults come from
``dataclasses.fields``, validation rules are the message literals lifted
(via ast) out of each section's ``validate()``, and the registry values
come from the live registries (strategies, transport codecs, partitioner
grammar, mesh kinds).  CI regenerates and ``git diff --exit-code``s the
result, so the reference cannot drift from the code (see Makefile
``check-docs``).  ``--check`` exits 1 if the file on disk is stale.
"""
from __future__ import annotations

import ast
import dataclasses
import inspect
import os
import re
import sys
import textwrap

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.api import spec as spec_mod                       # noqa: E402
from repro.serve import spec as serve_spec_mod               # noqa: E402
from repro.compress import transport                         # noqa: E402
from repro.core import strategies                            # noqa: E402
from repro.data import federated                             # noqa: E402
from repro.launch import mesh as mesh_mod                    # noqa: E402
from repro.models import registry as model_registry          # noqa: E402

OUT = os.path.join(REPO, "docs", "SPEC.md")


# ---------------------------------------------------------------------------
# field + validator introspection
# ---------------------------------------------------------------------------

def _default_repr(f: dataclasses.Field) -> str:
    if f.default is not dataclasses.MISSING:
        return repr(f.default)
    if f.default_factory is not dataclasses.MISSING:  # type: ignore
        return repr(f.default_factory())
    return "—"


def _fstring_text(node: ast.AST) -> str:
    """Render a (possibly f-) string AST node as readable rule text with
    ``{expr}`` placeholders for interpolated values."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.JoinedStr):
        parts = []
        for v in node.values:
            if isinstance(v, ast.Constant):
                parts.append(str(v.value))
            elif isinstance(v, ast.FormattedValue):
                parts.append("{%s}" % ast.unparse(v.value))
        return "".join(parts)
    return ast.unparse(node)


def _validation_rules(cls) -> list:
    """Message literals from ``_require(cond, msg)`` and
    ``raise SpecError(msg)`` inside ``cls.validate``."""
    validate = getattr(cls, "validate", None)
    if validate is None:
        return []
    tree = ast.parse(textwrap.dedent(inspect.getsource(validate)))
    rules = []
    for node in ast.walk(tree):
        msg = None
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "_require" and len(node.args) == 2):
            msg = node.args[1]
        elif (isinstance(node, ast.Raise) and node.exc is not None
              and isinstance(node.exc, ast.Call)
              and ast.unparse(node.exc.func).endswith("SpecError")
              and node.exc.args):
            msg = node.exc.args[0]
        if msg is not None:
            text = " ".join(_fstring_text(msg).split())
            rules.append(text)
    return rules


def _doc_summary(cls) -> str:
    doc = inspect.getdoc(cls) or ""
    return " ".join(doc.split("\n\n")[0].split())


def _field_note(cls, name: str) -> str:
    """The ``#:`` comment right above a field, or the inline comment on
    its line — the same conventions the source uses."""
    lines = inspect.getsource(cls).splitlines()
    note: list = []
    for line in lines:
        s = line.strip()
        if s.startswith("#:"):
            note.append(s[2:].strip())
        elif s.startswith(f"{name}:") or s.startswith(f"{name} "):
            # a trailing comment is separated from code by 2+ spaces,
            # which a '#' inside a string default never is
            m = re.search(r"\s{2,}#\s*(.+)$", line)
            if m:
                return m.group(1).strip()
            return " ".join(note)
        elif not s.startswith("#"):
            note = []
    return ""


def section_md(name: str, cls) -> str:
    out = [f"## `{name}` — {cls.__name__}", "", _doc_summary(cls), ""]
    out += ["| field | type | default | notes |",
            "|-------|------|---------|-------|"]
    for f in dataclasses.fields(cls):
        ftype = f.type if isinstance(f.type, str) else getattr(
            f.type, "__name__", str(f.type))
        note = _field_note(cls, f.name).replace("|", "\\|")
        out.append(f"| `{f.name}` | `{ftype}` | `{_default_repr(f)}` "
                   f"| {note} |")
    rules = _validation_rules(cls)
    if rules:
        out += ["", "Validation (each failure raises `SpecError` with "
                    "this message):", ""]
        out += [f"- {r}" for r in rules]
    out.append("")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

def registries_md() -> str:
    out = ["## Registries", "",
           "The open extension points the spec's string fields resolve "
           "through.", "",
           "### Models (`data.model`)", "",
           "Registered in `models/registry.py` "
           "(`register_model(name, factory)`); each entry binds an "
           "`FLModel` (init_params / apply / loss / eval_metrics / "
           "batch_shape) to the scenario's data dims and declares the "
           "data kind the partitioner synthesizes.  The v1/v2 "
           "`data.task` values migrate: "
           + ", ".join(f"`{t}` → `{m}`" for t, m in
                       sorted(model_registry.LEGACY_TASKS.items()))
           + ".", ""]
    for name in model_registry.registered_models():
        m = model_registry.build_model(name, model_registry.DataDims())
        out.append(f"- `{name}` — data kind `{m.data_kind}`, per-sample "
                   f"input `{tuple(m.batch_shape)}` "
                   f"{np.dtype(m.batch_dtype).name}")
    out += ["", "### Strategies (`strategy.name`)", "",
           "Registered in `core/strategies/STRATEGIES`; "
           "`strategy.kwargs` is checked against the constructor "
           "signature.", ""]
    for name in sorted(strategies.STRATEGIES):
        factory = strategies.STRATEGIES[name]
        sig = ", ".join(p for p in inspect.signature(factory).parameters)
        out.append(f"- `{name}` — kwargs: `{sig or '(none)'}`")
    out += ["", "### Transport codecs (`transport.codec`)", "",
            "Registered via `compress/transport.register_codec`; "
            "`null` keeps each strategy's paper default link.", ""]
    for name in transport.registered_codecs():
        out.append(f"- `{name}`")
    out += ["", "### Partitioners (`data.partitioner`)", "",
            " ".join((inspect.getdoc(federated.parse_partitioner) or "")
                     .split()), "",
            "### Mesh kinds (`mesh.kind`)", ""]
    for kind in mesh_mod.MESH_KINDS:
        d = mesh_mod.STATIC_DATA_AXIS.get(kind)
        axis = (f"data axis {d}" if d else
                "data axis = local device count / n_pods")
        out.append(f"- `{kind}` — {axis}")
    out.append("")
    return "\n".join(out)


def build() -> str:
    head = [
        "<!-- GENERATED by scripts/gen_spec_docs.py — do not edit; "
        "run `make docs`. -->",
        "",
        "# ExperimentSpec reference",
        "",
        " ".join((inspect.getdoc(spec_mod) or "").split("\n\n")[0]
                 .split()),
        "",
        f"Spec version: **{spec_mod.SPEC_VERSION}** (readable: "
        f"{list(spec_mod._READABLE_VERSIONS)}).  Serialization is strict "
        "JSON via `to_dict`/`from_dict`; `spec.hash()` (sha256 of the "
        "canonical JSON, 12 hex chars) stamps every result for "
        "provenance.  See `DESIGN.md` §API for the architecture and "
        "`README.md` for the quickstart.",
        "",
    ]
    body = [section_md(name, cls)
            for name, cls in spec_mod._SECTIONS.items()]
    serve = [
        "## `serve` — ServeSpec (serving plane, not an ExperimentSpec "
        "section)",
        "",
        " ".join((inspect.getdoc(serve_spec_mod) or "")
                 .split("\n\n")[0].split()),
        "",
        section_md("serve", serve_spec_mod.ServeSpec)
        .split("\n", 2)[2],  # drop the duplicate header, keep the table
    ]
    return "\n".join(head + body + serve + [registries_md()])


def main() -> None:
    doc = build()
    if "--check" in sys.argv:
        on_disk = open(OUT).read() if os.path.exists(OUT) else ""
        if on_disk != doc:
            sys.exit(f"{OUT} is stale; run `make docs` and commit the "
                     "result")
        print(f"{OUT} is up to date")
        return
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        f.write(doc)
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
