"""Fail on broken intra-repo markdown links.

    python scripts/check_links.py [files...]      # default: all *.md

Checks every ``[text](target)`` whose target is not an external URL
(``http(s)://``, ``mailto:``) or a pure in-page anchor: the referenced
file must exist relative to the markdown file (or the repo root as a
fallback, matching how links read on GitHub from the root README).
Anchors on intra-repo links are stripped — heading slugs are a rendering
concern; file existence is the invariant CI can hold cheaply.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: [text](target) — excluding images' alt-text edge cases is unnecessary;
#: image targets are checked the same way
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files() -> list:
    out = []
    for root, dirs, files in os.walk(REPO):
        dirs[:] = [d for d in dirs if not d.startswith(".")
                   and d != "__pycache__"]
        out += [os.path.join(root, f) for f in files if f.endswith(".md")]
    return sorted(out)


def check_file(path: str) -> list:
    errors = []
    text = open(path, encoding="utf-8").read()
    # ignore fenced code blocks: link-looking text in examples is not a link
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        cand = [os.path.normpath(os.path.join(os.path.dirname(path), rel)),
                os.path.normpath(os.path.join(REPO, rel))]
        if not any(os.path.exists(c) for c in cand):
            errors.append(f"{os.path.relpath(path, REPO)}: broken link "
                          f"-> {target}")
    return errors


def main() -> None:
    files = [os.path.abspath(f) for f in sys.argv[1:]] or md_files()
    errors = []
    for f in files:
        errors += check_file(f)
    if errors:
        print("\n".join(errors))
        sys.exit(f"{len(errors)} broken intra-repo link(s)")
    print(f"checked {len(files)} markdown file(s): all intra-repo links "
          "resolve")


if __name__ == "__main__":
    main()
