"""All four server strategies through the unified engine, plus FedAT over
each transport codec (polyline vs the Pallas-kernel int8/int16 quantizer).

    PYTHONPATH=src python examples/strategy_codecs.py
"""
from repro.core.engine import EngineConfig, run_strategy
from repro.core.simulation import SimConfig, SimEnv


def main():
    env = SimEnv(SimConfig(n_clients=20, n_tiers=4, classes_per_client=2,
                           samples_per_client=40, image_hw=8,
                           clients_per_round=5, local_epochs=2,
                           n_unstable=2))
    cfg = EngineConfig(total_updates=40, eval_every=10)

    print("strategy sweep (one event loop, four policies)")
    print("              acc    var      sim-time  MB")
    for name in ("fedat", "fedavg", "tifl", "fedasync"):
        m = run_strategy(env, name, cfg)
        s = m.summary()
        print(f"  {name:8s} {s['best_acc']:.3f}  {s['final_var']:.4f}  "
              f"{s['sim_time']:8.0f}s  {s['total_mb']:6.1f}")

    print("\nFedAT codec sweep (same protocol, different links)")
    print("              acc    MB")
    for codec in ("none", "polyline:4", "quantize8", "quantize16"):
        m = run_strategy(env, "fedat", cfg, codec=codec)
        s = m.summary()
        print(f"  {codec:11s} {s['best_acc']:.3f}  {s['total_mb']:6.1f}")


if __name__ == "__main__":
    main()
