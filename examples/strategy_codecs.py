"""The scenario plane as sweeps: all four server strategies through the
unified engine, then FedAT over each transport codec — both as cartesian
grids over one base ExperimentSpec (shared cached environment).

    PYTHONPATH=src python examples/strategy_codecs.py
"""
from repro import api


def main():
    base = api.ExperimentSpec(
        data=api.DataSpec(n_clients=20, classes_per_client=2,
                          samples_per_client=40, image_hw=8),
        tiers=api.TierSpec(n_tiers=4, clients_per_round=5, n_unstable=2),
        engine=api.EngineSpec(total_updates=40, eval_every=10,
                              local_epochs=2))

    print("strategy sweep (one event loop, four policies)")
    print("              acc    var      sim-time  MB")
    for res in api.sweep(base, {"strategy.name": ["fedat", "fedavg",
                                                  "tifl", "fedasync"]}):
        s = res.metrics.summary()
        name = res.spec.strategy.name
        print(f"  {name:8s} {s['best_acc']:.3f}  {s['final_var']:.4f}  "
              f"{s['sim_time']:8.0f}s  {s['total_mb']:6.1f}")

    print("\nFedAT codec sweep (same protocol, different links)")
    print("              acc    MB      spec")
    for res in api.sweep(base, {"transport.codec": ["none", "polyline:4",
                                                    "quantize8",
                                                    "quantize16"]}):
        s = res.metrics.summary()
        print(f"  {res.spec.transport.codec:11s} {s['best_acc']:.3f}  "
              f"{s['total_mb']:6.1f}  {res.spec_hash}")


if __name__ == "__main__":
    main()
