"""Serve a small model with batched requests + continuous batching.

    PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-2.7b]

Uses the reduced (smoke) config of the chosen architecture so it runs on
CPU; the same Server class drives the full configs on TPU.
"""
import argparse
import time

import numpy as np

from repro.configs.registry import get_smoke_config
from repro.launch.serve import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(4, 24))),
                    args.max_new)
            for i in range(args.requests)]
    server = Server(cfg, batch_slots=args.slots, max_len=128)
    t0 = time.time()
    done, steps = server.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"arch={cfg.name}: served {len(done)} requests "
          f"({toks} tokens) in {dt:.1f}s over {steps} decode steps "
          f"with {args.slots} slots (continuous batching)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
