"""Train a federated LM, checkpoint it, then serve that checkpoint.

    PYTHONPATH=src python examples/serve_lm.py

The full production path in ~60 lines: a declarative ExperimentSpec
trains ``tiny_lm`` for two federated rounds, ``Run.run`` writes the
params plus a ``spec.json`` provenance sidecar, and the serving plane
resolves the directory by spec hash — refusing silently-wrong weights —
before decoding live requests with continuous batching.
"""
import argparse
import tempfile

from repro import api
from repro.serve import (ServeEngine, ServeSpec, load_checkpoint,
                         make_requests, report)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (req/s); 0 = closed burst")
    args = ap.parse_args()

    spec = api.ExperimentSpec().with_overrides({
        "data.model": "tiny_lm", "data.n_clients": 8,
        "tiers.n_tiers": 2, "tiers.n_unstable": 0,
        "tiers.clients_per_round": 2,
        "engine.total_updates": args.rounds,
    }).validate()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        print(f"training spec {spec.hash()} for {args.rounds} rounds ...")
        res = api.build(spec).run(checkpoint_dir=ckpt_dir)
        print(f"  final acc {res.metrics.summary()['best_acc']:.3f}; "
              f"checkpoint -> {ckpt_dir}")

        loaded = load_checkpoint(ckpt_dir, expect_spec=spec)
        print(f"loaded {loaded.spec.data.model} @ spec {loaded.spec_hash} "
              f"(step {loaded.step})")

        sspec = ServeSpec(slots=args.slots, max_len=64, prefill_len=16,
                          max_new=args.max_new)
        reqs = make_requests(args.requests, args.rate, sspec.prefill_len,
                             args.max_new, loaded.config.vocab_size, seed=0)
        engine = ServeEngine(loaded.config, loaded.lm_params, sspec)
        done = engine.run(reqs)

    r = report(done)
    print(f"served {r['requests']} requests ({r['tokens']} tokens) at "
          f"{r['tok_per_s']:.1f} tok/s — p50/p95 latency "
          f"{r['latency_p50_s']:.3f}/{r['latency_p95_s']:.3f}s "
          f"(traces: {engine.trace_counts})")
    for req in done[:3]:
        print(f"  req {req.rid}: prompt[{len(req.prompt)}] -> {req.out}")


if __name__ == "__main__":
    main()
