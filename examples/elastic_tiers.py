"""Elastic FedAT: lose a tier mid-training, keep going, regain it later.

    PYTHONPATH=src python examples/elastic_tiers.py

Demonstrates the fault-tolerance story at the protocol level: shrink_pods
drops a failed tier (Eq. 3 weights renormalize over survivors), grow_pods
bootstraps a replacement from the weighted global model.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig
from repro.configs.registry import get_smoke_config
from repro.core import steps as steps_mod
from repro.runtime import elastic, sharding as shd


def main():
    cfg = get_smoke_config("qwen2-7b")
    tcfg = TrainConfig(lr=1e-3, fedat_enabled=True, fedat_sync_every=2,
                       fedat_compress_bits=8)
    n = len(jax.devices())
    mesh = jax.make_mesh((1, n, 1), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)

    def batch(n_pods, seed):
        toks = np.random.default_rng(seed).integers(
            0, cfg.vocab_size, (n_pods, 4, 128)).astype(np.int32)
        return {"tokens": jnp.asarray(toks)}

    with mesh, shd.use_mesh(mesh):
        fns = steps_mod.make_fedat_step(cfg, tcfg, mesh)
        state = jax.jit(fns.init_state)(jax.random.PRNGKey(0))

        # phase 1: train with 1 pod-slot, then grow to 3 tiers
        fn = jax.jit(fns.train_step)
        for i in range(3):
            state, m = fn(state, batch(1, i))
        print(f"phase 1 (1 tier): loss {float(m['loss']):.3f}, "
              f"counts {np.asarray(state['counts'])}")

        # phase 2: two new tiers join — they bootstrap from the Eq. 3
        # global model with zero update count
        state = elastic.grow_pods(state, 2)
        print(f"grew to {state['counts'].shape[0]} tiers, "
              f"counts {np.asarray(state['counts'])}")
        # (on a real cluster the step is re-jitted for the 3-slot mesh here)

        # phase 3: tier 1 fails permanently; survivors carry on
        state = elastic.shrink_pods(state, keep=[0, 2])
        print(f"shrunk to {state['counts'].shape[0]} tiers after failure, "
              f"counts {np.asarray(state['counts'])}")
        print("params finite:",
              bool(all(np.isfinite(np.asarray(l)).all()
                       for l in jax.tree.leaves(state["params"]))))


if __name__ == "__main__":
    main()
