"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
FedAT multi-pod step (pods-as-tiers), fault injection, checkpoint/resume.

    PYTHONPATH=src python examples/tiered_pretrain.py [--steps 200]

On CPU this uses a ~100M-param qwen2-style config at short sequence length;
on a real cluster the same driver takes --arch qwen2-7b --shape train_4k.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import TrainConfig
from repro.configs.shapes import ShapeConfig
from repro.configs.tiny_lm import dense_lm
from repro.checkpoint import CheckpointManager
from repro.core import steps as steps_mod
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.runtime import sharding as shd
from repro.runtime.fault import GuardedRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (use on real hardware)")
    ap.add_argument("--ckpt-dir", default="/tmp/tiered_pretrain")
    args = ap.parse_args()

    # --full => ~100M params (12L, d=768); the ~14M default keeps the
    # example CPU-friendly.  Sized via the shared configs/tiny_lm.dense_lm
    # builder so model shapes are named in exactly one place.
    cfg = dense_lm(768, 12) if args.full else dense_lm(320, 6)
    print(f"model: {cfg.param_count()/1e6:.0f}M params")
    shape = ShapeConfig("example", args.seq, args.batch, "train")
    tcfg = TrainConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps,
                       fedat_enabled=True, fedat_sync_every=4,
                       fedat_compress_bits=8)
    mesh = make_host_mesh(n_pods=2)
    multi = "pod" in mesh.shape
    n_pods = mesh.shape.get("pod", 1)
    print(f"mesh: {dict(mesh.shape)} (fedat multi-pod: {multi})")

    with mesh, shd.use_mesh(mesh):
        fns = (steps_mod.make_fedat_step if multi else
               steps_mod.make_single_pod_step)(cfg, tcfg, mesh)
        step_fn = jax.jit(fns.train_step,
                          in_shardings=(fns.state_shardings,
                                        fns.batch_shardings),
                          out_shardings=(fns.state_shardings, None))
        state = jax.jit(fns.init_state,
                        out_shardings=fns.state_shardings)(
            jax.random.PRNGKey(0))

        pipe = TokenPipeline(cfg, shape)
        ckpt = CheckpointManager(args.ckpt_dir, keep=2)

        def batches():
            s = 0
            while True:
                b = pipe.batch(s)
                if multi:
                    b = steps_mod.split_batch_for_pods(b, n_pods)
                yield b
                s += 1

        losses = []

        def on_metrics(step, m):
            losses.append(float(m["loss"]))
            if step % 20 == 0:
                print(f"  step {step:4d}  loss {losses[-1]:.4f}")

        runner = GuardedRunner(step_fn, ckpt, ckpt_every=50,
                               inject_failure_rate=0.01, seed=0)
        t0 = time.time()
        state, end = runner.run(state, batches(), args.steps,
                                on_metrics=on_metrics)
        dt = time.time() - t0
    print(f"\ntrained {end} steps in {dt:.0f}s ({dt/end:.2f}s/step); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"runner stats {runner.stats}")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
