"""Quickstart: FedAT vs FedAvg on synthetic non-IID data in ~2 minutes (CPU).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.baselines import BaselineConfig, run_fedavg
from repro.core.fedat import FedATConfig, run_fedat
from repro.core.simulation import SimConfig, SimEnv


def main():
    # 20 clients, 4 latency tiers (the paper's delay bands), 2-class non-IID
    env = SimEnv(SimConfig(n_clients=20, n_tiers=4, classes_per_client=2,
                           samples_per_client=40, image_hw=8,
                           clients_per_round=5, local_epochs=2,
                           n_unstable=2))
    print(f"tiers: {[len(m) for m in env.tm.members]} clients each; "
          f"latencies {env.tm.latencies.min():.1f}..{env.tm.latencies.max():.1f}s")

    fedat = run_fedat(env, FedATConfig(total_updates=60, eval_every=10))
    fedavg = run_fedavg(env, BaselineConfig(total_updates=40, eval_every=10))

    print("\n              acc    var      sim-time  MB")
    for name, m in (("FedAT", fedat), ("FedAvg", fedavg)):
        s = m.summary()
        print(f"  {name:8s} {s['best_acc']:.3f}  {s['final_var']:.4f}  "
              f"{s['sim_time']:8.0f}s  {s['total_mb']:6.1f}")
    t = 0.35
    tf, ta = fedat.time_to_accuracy(t), fedavg.time_to_accuracy(t)
    if tf and ta:
        print(f"\n  time to {t:.0%} accuracy: FedAT {tf:.0f}s vs "
              f"FedAvg {ta:.0f}s  ({ta / tf:.1f}x faster)")


if __name__ == "__main__":
    main()
