"""Quickstart: FedAT vs FedAvg from one declarative ExperimentSpec (~2
minutes on CPU; --updates 12 is the CI smoke setting).

    PYTHONPATH=src python examples/quickstart.py [--updates N]

One spec describes the whole scenario — data skew, latency tiers, dropout,
link codec, budget; strategies are swapped with a dotted-path override and
share the cached environment (identical partitions/latencies/dropouts).
"""
import argparse

from repro import api


def main(updates: int = 60):
    # 20 clients, 4 latency tiers (the paper's delay bands), 2-class non-IID
    spec = api.ExperimentSpec(
        data=api.DataSpec(n_clients=20, classes_per_client=2,
                          samples_per_client=40, image_hw=8),
        tiers=api.TierSpec(n_tiers=4, clients_per_round=5, n_unstable=2),
        strategy=api.StrategySpec("fedat"),
        engine=api.EngineSpec(total_updates=updates, eval_every=10,
                              local_epochs=2))

    run = api.build(spec)
    env = run.env
    print(f"spec {spec.hash()}; tiers: {[len(m) for m in env.tm.members]} "
          f"clients each; latencies {env.tm.latencies.min():.1f}.."
          f"{env.tm.latencies.max():.1f}s")

    fedat = run.run()
    fedavg = api.run_spec(spec.with_overrides(
        {"strategy.name": "fedavg",
         "engine.total_updates": max(2 * updates // 3, 1)}))

    print("\n              acc    var      sim-time  MB       spec")
    for name, res in (("FedAT", fedat), ("FedAvg", fedavg)):
        s = res.metrics.summary()
        print(f"  {name:8s} {s['best_acc']:.3f}  {s['final_var']:.4f}  "
              f"{s['sim_time']:8.0f}s  {s['total_mb']:6.1f}  "
              f"{res.spec_hash}")
    t = 0.35
    tf = fedat.metrics.time_to_accuracy(t)
    ta = fedavg.metrics.time_to_accuracy(t)
    if tf and ta:
        print(f"\n  time to {t:.0%} accuracy: FedAT {tf:.0f}s vs "
              f"FedAvg {ta:.0f}s  ({ta / tf:.1f}x faster)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--updates", type=int, default=60,
                    help="FedAT global update budget (CI smoke uses 12)")
    main(ap.parse_args().updates)
