"""Engine parity: each strategy run through core/engine.py reproduces the
seed (pre-refactor) per-method event loops' Metrics trajectory, plus
determinism (same seed -> identical metrics across two runs).

The reference implementations below are verbatim-compact copies of the
deleted loops from core/fedat.py and core/baselines.py at the seed commit;
they are the oracle the unified engine must match.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation
from repro.core.baselines import BaselineConfig, run_fedavg, run_fedasync, \
    run_tifl
from repro.core.fedat import FedATConfig, fake_polyline, measure_ratio, \
    run_fedat
from repro.core.scheduler import EventQueue, Metrics
from repro.core.simulation import SimConfig, SimEnv
from repro.core.tiering import sample_round_latency


@pytest.fixture(scope="module")
def env():
    return SimEnv(SimConfig(n_clients=15, n_tiers=3, samples_per_client=30,
                            classes_per_client=2, image_hw=8,
                            clients_per_round=4, local_epochs=2,
                            n_unstable=2))


# ---------------------------------------------------------------------------
# seed reference implementations (the oracle)
# ---------------------------------------------------------------------------

def _seed_fedat(env, fc):
    sc = env.sc
    M = env.tm.n_tiers
    rng = np.random.default_rng(fc.seed + 17)
    tier_models = jax.tree.map(lambda l: jnp.stack([l] * M), env.params0)
    counts = np.zeros(M, np.int64)
    w_global = env.params0
    update_fn = env.update_fn if fc.use_prox else env.update_fn_noprox
    ratio = measure_ratio(env.params0, fc.precision)
    q = EventQueue()
    metrics = Metrics()
    bytes_up = bytes_down = 0.0
    t_global = 0
    for m in range(M):
        ids = env.sample_clients(env.tm.members[m], sc.clients_per_round, rng)
        q.push(sample_round_latency(env.tm, m, ids, rng), (m, ids))
    while t_global < fc.total_updates and len(q):
        now, (m, ids) = q.pop()
        alive = env.alive(now)
        ids = ids[alive[ids]]
        if len(ids) == 0:
            ids = env.sample_clients(
                env.tm.members[m][alive[env.tm.members[m]]],
                sc.clients_per_round, rng)
            if len(ids) == 0:
                continue
            q.push(sample_round_latency(env.tm, m, ids, rng), (m, ids))
            continue
        w_sent = fake_polyline(w_global, fc.precision)
        bytes_down += len(ids) * env.model_bytes * ratio
        rngs = jax.random.split(jax.random.PRNGKey(rng.integers(2**31)),
                                len(ids))
        client_params, _ = update_fn(w_sent, env.client_batch(ids), rngs)
        client_params = fake_polyline(client_params, fc.precision)
        bytes_up += len(ids) * env.model_bytes * ratio
        tier_model = aggregation.intra_tier_average(client_params,
                                                    env.n_samples(ids))
        tier_models = jax.tree.map(
            lambda s, nw: s.at[m].set(nw), tier_models, tier_model)
        counts[m] += 1
        t_global += 1
        if fc.weighted:
            w_global = aggregation.global_model(tier_models,
                                                jnp.asarray(counts))
        else:
            w_global = aggregation.weighted_average(
                tier_models, aggregation.uniform_weights(M))
        nxt = env.sample_clients(
            env.tm.members[m][alive[env.tm.members[m]]],
            sc.clients_per_round, rng)
        if len(nxt):
            q.push(sample_round_latency(env.tm, m, nxt, rng), (m, nxt))
        if t_global % fc.eval_every == 0 or t_global == fc.total_updates:
            acc, var = env.evaluate(w_global)
            ratio = measure_ratio(w_global, fc.precision)
            metrics.record(now, t_global, acc, var, bytes_up, bytes_down)
    return metrics


def _seed_fedavg(env, bc):
    sc = env.sc
    rng = np.random.default_rng(bc.seed + 29)
    w = env.params0
    q = EventQueue()
    metrics = Metrics()
    bytes_up = bytes_down = 0.0
    for t in range(1, bc.total_updates + 1):
        alive = env.alive(q.now)
        pool = np.arange(sc.n_clients)[alive]
        ids = env.sample_clients(pool, sc.clients_per_round, rng)
        if len(ids) == 0:
            break
        q.push(sample_round_latency(env.tm, -1, ids, rng), None)
        q.pop()
        bytes_down += len(ids) * env.model_bytes
        rngs = jax.random.split(jax.random.PRNGKey(rng.integers(2**31)),
                                len(ids))
        client_params, _ = env.update_fn_noprox(w, env.client_batch(ids), rngs)
        bytes_up += len(ids) * env.model_bytes
        w = aggregation.intra_tier_average(client_params, env.n_samples(ids))
        if t % bc.eval_every == 0 or t == bc.total_updates:
            acc, var = env.evaluate(w)
            metrics.record(q.now, t, acc, var, bytes_up, bytes_down)
    return metrics


def _seed_tifl(env, bc):
    sc = env.sc
    rng = np.random.default_rng(bc.seed + 31)
    w = env.params0
    q = EventQueue()
    metrics = Metrics()
    bytes_up = bytes_down = 0.0
    for t in range(1, bc.total_updates + 1):
        m = int(rng.integers(env.tm.n_tiers))
        alive = env.alive(q.now)
        pool = env.tm.members[m][alive[env.tm.members[m]]]
        ids = env.sample_clients(pool, sc.clients_per_round, rng)
        if len(ids) == 0:
            continue
        q.push(sample_round_latency(env.tm, m, ids, rng), None)
        q.pop()
        bytes_down += len(ids) * env.model_bytes
        rngs = jax.random.split(jax.random.PRNGKey(rng.integers(2**31)),
                                len(ids))
        client_params, _ = env.update_fn_noprox(w, env.client_batch(ids), rngs)
        bytes_up += len(ids) * env.model_bytes
        w = aggregation.intra_tier_average(client_params, env.n_samples(ids))
        if t % bc.eval_every == 0 or t == bc.total_updates:
            acc, var = env.evaluate(w)
            metrics.record(q.now, t, acc, var, bytes_up, bytes_down)
    return metrics


def _seed_fedasync(env, bc):
    sc = env.sc
    rng = np.random.default_rng(bc.seed + 37)
    w = env.params0
    q = EventQueue()
    metrics = Metrics()
    bytes_up = bytes_down = 0.0
    server_version = 0
    for c in range(sc.n_clients):
        q.push(float(env.tm.latencies[c]), (int(c), server_version))
    t = 0
    while t < bc.total_updates and len(q):
        now, (c, start_version) = q.pop()
        if not env.alive(now)[c]:
            continue
        bytes_down += env.model_bytes
        rngs = jax.random.split(jax.random.PRNGKey(rng.integers(2**31)), 1)
        ids = np.asarray([c])
        client_params, _ = env.update_fn_noprox(w, env.client_batch(ids), rngs)
        client_w = jax.tree.map(lambda a: a[0], client_params)
        bytes_up += env.model_bytes
        staleness = server_version - start_version
        a_eff = bc.alpha * (1.0 + staleness) ** (-bc.staleness_exp)
        w = jax.tree.map(lambda g, l: (1 - a_eff) * g + a_eff * l, w, client_w)
        server_version += 1
        t += 1
        q.push(float(env.tm.latencies[c]) * (1 + rng.uniform(0, 0.1)),
               (c, server_version))
        if t % bc.eval_every == 0 or t == bc.total_updates:
            acc, var = env.evaluate(w)
            metrics.record(now, t, acc, var, bytes_up, bytes_down)
    return metrics


# ---------------------------------------------------------------------------
# parity + determinism
# ---------------------------------------------------------------------------

def _assert_trajectory_close(m_new, m_ref, bytes_rtol=0.05):
    """Rounds/times/accuracy must match the seed loop; bytes are allowed a
    tolerance for the sampled wire-ratio accounting approximation."""
    assert m_new.rounds == m_ref.rounds
    np.testing.assert_allclose(m_new.times, m_ref.times, rtol=1e-9)
    np.testing.assert_allclose(m_new.acc, m_ref.acc, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(m_new.acc_var, m_ref.acc_var,
                               rtol=1e-5, atol=1e-9)
    np.testing.assert_allclose(m_new.bytes_up, m_ref.bytes_up,
                               rtol=bytes_rtol)
    np.testing.assert_allclose(m_new.bytes_down, m_ref.bytes_down,
                               rtol=bytes_rtol)


@pytest.mark.parametrize("precision", [4, None])
def test_fedat_parity(env, precision):
    fc = FedATConfig(total_updates=20, eval_every=5, precision=precision)
    _assert_trajectory_close(run_fedat(env, fc), _seed_fedat(env, fc))


def test_fedat_parity_unweighted_noprox(env):
    fc = FedATConfig(total_updates=12, eval_every=6, weighted=False,
                     use_prox=False)
    _assert_trajectory_close(run_fedat(env, fc), _seed_fedat(env, fc))


def test_fedavg_parity(env):
    bc = BaselineConfig(total_updates=12, eval_every=4)
    _assert_trajectory_close(run_fedavg(env, bc), _seed_fedavg(env, bc))


def test_tifl_parity(env):
    bc = BaselineConfig(total_updates=12, eval_every=4)
    _assert_trajectory_close(run_tifl(env, bc), _seed_tifl(env, bc))


def test_fedasync_parity(env):
    bc = BaselineConfig(total_updates=20, eval_every=5)
    _assert_trajectory_close(run_fedasync(env, bc), _seed_fedasync(env, bc))


def test_determinism_same_seed_identical_metrics(env):
    fc = FedATConfig(total_updates=10, eval_every=5, seed=3)
    m1, m2 = run_fedat(env, fc), run_fedat(env, fc)
    assert m1.rounds == m2.rounds
    assert m1.times == m2.times
    assert m1.acc == m2.acc
    assert m1.bytes_up == m2.bytes_up and m1.bytes_down == m2.bytes_down

    bc = BaselineConfig(total_updates=8, eval_every=4, seed=3)
    for fn in (run_fedavg, run_tifl, run_fedasync):
        a, b = fn(env, bc), fn(env, bc)
        assert a.times == b.times and a.acc == b.acc


def test_seed_changes_trajectory(env):
    m0 = run_fedat(env, FedATConfig(total_updates=8, eval_every=8, seed=0))
    m1 = run_fedat(env, FedATConfig(total_updates=8, eval_every=8, seed=1))
    assert m0.times != m1.times
