"""Topology plane (core/topology.py + spec topology section):
hierarchical geo-distributed federation — clients -> edge aggregators ->
regional silos -> global server — with per-link WAN delay bands,
per-link codecs, and delayed-gradient compensation.

The two bitwise anchors of the plane:

  * specs with the *default* topology section map to
    ``SimConfig.topology = None`` and run the flat engine byte-for-byte
    (the engine-parity oracle covers that side);
  * a *degenerate* active topology (1 silo, 1 edge, zero-width delay
    bands, default codecs) must replay the flat FedAT run bitwise —
    singleton Eq. 4 / Eq. 3 averages are exact identities, the extra
    pins are neutral, and the dedicated link-delay stream draws exactly
    0.0 WAN delay.
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import api
from repro.core import topology as topology_mod
from repro.core.scheduler import Metrics


def _base(**overrides):
    kw = dict(
        data=api.DataSpec(n_clients=24, samples_per_client=24, image_hw=8),
        tiers=api.TierSpec(n_tiers=1, clients_per_round=4, n_unstable=0),
        engine=api.EngineSpec(total_updates=8, eval_every=4,
                              local_epochs=1),
        strategy=api.StrategySpec("fedat"),
    )
    kw.update(overrides)
    return api.ExperimentSpec(**kw)


def _metrics_fields(m):
    return [getattr(m, f.name) for f in dataclasses.fields(Metrics)]


# ---------------------------------------------------------------------------
# spec surface
# ---------------------------------------------------------------------------

def test_topology_spec_round_trip():
    spec = _base(topology=api.TopologySpec(
        n_silos=2, edges_per_silo=2, clients_per_edge=2,
        delay={"client_edge": (0.5, 1.5), "silo_global": (2.0, 6.0)},
        codec={"silo_global": "quantize8"},
        compensation=0.5, silo_skew=0.25, seed=3))
    back = api.ExperimentSpec.from_json(spec.to_json())
    assert back == spec and back.hash() == spec.hash()
    # delay bands arrive as lists from JSON but compare as tuples
    assert back.topology.delay["client_edge"] == (0.5, 1.5)


def test_default_topology_section_is_inert():
    spec = _base()
    assert spec.topology.to_config() is None
    assert spec.to_sim_config().topology is None
    # seed alone stays inert (no delay/codec/extra structure to seed)
    assert api.TopologySpec(seed=7).to_config() is None


def test_topology_validation_errors():
    for topo, msg in [
        (api.TopologySpec(n_silos=0), "n_silos"),
        (api.TopologySpec(n_silos=30), "n_clients"),
        (api.TopologySpec(n_silos=2, delay={"wan": (0, 1)}),
         r"client_edge.*edge_silo.*silo_global"),
        (api.TopologySpec(n_silos=2, codec={"lan": "none"}),
         r"client_edge.*edge_silo.*silo_global"),
        (api.TopologySpec(n_silos=2, delay={"silo_global": (3.0, 1.0)}),
         "lo <= hi"),
        (api.TopologySpec(n_silos=2, codec={"silo_global": "zstd"}),
         "codec"),
        (api.TopologySpec(n_silos=2, compensation=1.5), "compensation"),
        (api.TopologySpec(n_silos=2, silo_skew=-0.5), "silo_skew"),
    ]:
        with pytest.raises(api.SpecError, match=msg):
            _base(topology=topo).validate()
    # the topology plane requires the tiered FedAT strategy
    with pytest.raises(api.SpecError, match="fedat"):
        _base(strategy=api.StrategySpec("fedavg"),
              topology=api.TopologySpec(n_silos=2)).validate()
    # ...and excludes the server-side validation gate (silo updates are
    # aggregates of aggregates; per-update gating is not defined yet)
    with pytest.raises(api.SpecError, match="gate"):
        _base(faults=api.FaultSpec(nan_rate=0.1),
              topology=api.TopologySpec(n_silos=2)).validate()


def test_per_edge_k_pad_error_names_the_field_path():
    """The mesh data-axis divisibility check fires for the
    topology-scoped per-edge K too, naming topology.clients_per_edge and
    hinting the nearest valid value."""
    with pytest.raises(api.SpecError,
                       match=r"topology\.clients_per_edge=10.*multiple "
                             r"of 16.*e\.g\. 16"):
        _base(tiers=api.TierSpec(n_tiers=1, clients_per_round=16,
                                 n_unstable=0),
              mesh=api.MeshSpec(kind="production"),
              topology=api.TopologySpec(
                  n_silos=2, clients_per_edge=10)).validate()


def test_topology_overrides_open_dicts():
    spec = _base().with_overrides({
        "topology.n_silos": 2,
        "topology.delay.silo_global": [1.0, 3.0],
        "topology.codec.client_edge": "quantize8"})
    assert spec.topology.n_silos == 2
    assert spec.topology.delay["silo_global"] == (1.0, 3.0)
    assert spec.topology.codec["client_edge"] == "quantize8"


# ---------------------------------------------------------------------------
# the degenerate bitwise contract
# ---------------------------------------------------------------------------

def test_degenerate_topology_is_bitwise_the_flat_run():
    """1 silo, 1 edge, zero-width delay band: the hierarchical path is
    an exact identity over the flat FedAT run — same floats, same byte
    counters, same event times."""
    flat = api.build(_base()).run().metrics
    degen = api.build(_base(topology=api.TopologySpec(
        n_silos=1, edges_per_silo=1,
        delay={"silo_global": (0.0, 0.0)}))).run().metrics
    assert _metrics_fields(flat) == _metrics_fields(degen)


# ---------------------------------------------------------------------------
# hierarchical runs
# ---------------------------------------------------------------------------

def test_multi_silo_reports_per_link_class_bytes():
    run = api.build(_base(topology=api.TopologySpec(
        n_silos=2, edges_per_silo=2, clients_per_edge=2,
        delay={"client_edge": (0.5, 1.5), "edge_silo": (1.0, 3.0),
               "silo_global": (2.0, 6.0)},
        codec={"client_edge": "quantize8", "silo_global": "quantize8"})))
    res = run.run()
    lb = run.strategy.link_bytes
    assert set(lb) == set(topology_mod.LINK_CLASSES)
    assert all(v > 0 for v in lb.values())
    # quantize8 on the client_edge hop: 4 padded clients' payloads per
    # round cost less than the 2 uncompressed edge_silo payloads x2
    assert lb["client_edge"] < lb["edge_silo"]
    assert res.metrics.times, "hierarchical run recorded no evals"


def test_compensation_changes_the_trajectory():
    """lambda > 0 adds the delayed-gradient correction on the stale silo
    path — a different (still deterministic) trajectory."""
    topo = dict(n_silos=2, edges_per_silo=2,
                delay={"silo_global": (5.0, 15.0)}, silo_skew=1.0)
    m0 = api.build(_base(topology=api.TopologySpec(**topo))).run().metrics
    m1 = api.build(_base(topology=api.TopologySpec(
        **topo, compensation=0.5))).run().metrics
    m1b = api.build(_base(topology=api.TopologySpec(
        **topo, compensation=0.5))).run().metrics
    assert m0.acc != m1.acc
    assert _metrics_fields(m1) == _metrics_fields(m1b)  # deterministic


# ---------------------------------------------------------------------------
# cross-plane: topology x faults x population
# ---------------------------------------------------------------------------

def test_silo_blackout_renormalizes_without_retrace():
    """A silo blackout drops its row from Eq. 3 (elastic renormalization
    over the survivors) and the return path re-bootstraps it — all
    through the one compiled topology step (zero retraces)."""
    run = api.build(_base(
        engine=api.EngineSpec(total_updates=14, eval_every=7,
                              local_epochs=1),
        faults=api.FaultSpec(blackouts=1, blackout_duration=40.0,
                             blackout_window=(10.0, 80.0)),
        topology=api.TopologySpec(n_silos=2, edges_per_silo=2,
                                  delay={"silo_global": (1.0, 3.0)})))
    res = run.run()
    assert res.metrics.times
    counts = run.env.executor().trace_counts
    topo_keys = [k for k in counts if k[0] == "fedat_topo"]
    assert len(topo_keys) == 1 and counts[topo_keys[0]] == 1


def test_churned_clients_never_reach_their_edge():
    """Churn that takes the whole population down for the whole run
    means no client update ever reaches an edge: the engine drains
    without committing a single global update (and without crashing)."""
    run = api.build(_base(
        faults=api.FaultSpec(churn_rate=1.0, churn_events=1,
                             churn_downtime=1e6, churn_window=(0.1, 0.2)),
        topology=api.TopologySpec(n_silos=2, edges_per_silo=2,
                                  delay={"silo_global": (1.0, 3.0)})))
    res = run.run()
    assert run.strategy.link_bytes["silo_global"] >= 0  # ledger intact
    # at most the pre-churn head of the run committed anything
    assert len(res.metrics.rounds) <= 1


def test_topology_composes_with_population_processes():
    spec = _base(
        population=api.PopulationSpec(availability="bernoulli:0.7:20",
                                      completion="bernoulli:0.8"),
        topology=api.TopologySpec(n_silos=2, edges_per_silo=2,
                                  delay={"silo_global": (1.0, 3.0)}))
    res = api.build(spec).run()
    assert res.metrics.times


def test_topology_composes_with_phone_profile():
    spec = _base(
        population=api.PopulationSpec(profile="phone:0.5"),
        topology=api.TopologySpec(n_silos=2, edges_per_silo=2,
                                  delay={"silo_global": (1.0, 3.0)}))
    res = api.build(spec).run()
    assert res.metrics.times


def test_crash_resume_is_bitwise_under_topology():
    """The engine snapshot carries the dispatch stack, the link-delay
    rng state, and the per-link byte ledger: an interrupted hierarchical
    run resumes to the exact uninterrupted trajectory."""
    import os
    spec = _base(
        engine=api.EngineSpec(total_updates=12, eval_every=2,
                              local_epochs=1),
        faults=api.FaultSpec(checkpoint_every=2, seed=4),
        topology=api.TopologySpec(n_silos=2, edges_per_silo=2,
                                  delay={"silo_global": (1.0, 3.0)},
                                  codec={"client_edge": "quantize8"},
                                  compensation=0.3))
    ref = api.build(spec).run().metrics

    class Abort(Exception):
        pass

    seen = []

    def bomb(point):
        seen.append(point)
        if len(seen) == 3:
            raise Abort

    import tempfile
    with tempfile.TemporaryDirectory() as ck:
        with pytest.raises(Abort):
            api.build(spec).run(on_eval=bomb, checkpoint_dir=ck)
        assert os.listdir(os.path.join(ck, "engine"))
        run = api.build(spec)
        res = run.run(checkpoint_dir=ck, resume_engine=True)
    assert _metrics_fields(res.metrics) == _metrics_fields(ref)
    assert all(v == 1 for v in run.env.executor().trace_counts.values())


# ---------------------------------------------------------------------------
# D == 1 mesh contract (forced 4-device host mesh, subprocess)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, json
    import numpy as np
    from repro import api
    from repro.core.scheduler import Metrics

    def mk(mesh):
        return api.ExperimentSpec(
            data=api.DataSpec(n_clients=24, samples_per_client=24,
                              image_hw=8),
            tiers=api.TierSpec(n_tiers=1, clients_per_round=4,
                               n_unstable=0),
            engine=api.EngineSpec(total_updates=6, eval_every=3,
                                  local_epochs=1),
            strategy=api.StrategySpec("fedat"),
            mesh=mesh,
            topology=api.TopologySpec(n_silos=2, edges_per_silo=2,
                                      delay={"silo_global": (1.0, 3.0)}))

    m0 = api.build(mk(api.MeshSpec(kind="single"))).run().metrics
    m1 = api.build(mk(api.MeshSpec(kind="host", n_pods=4))).run().metrics
    eq = all(getattr(m0, f.name) == getattr(m1, f.name)
             for f in dataclasses.fields(Metrics))
    print("RESULT" + json.dumps({"bitwise": eq, "times": m0.times}))
""")


def test_multi_silo_on_pod_axis_stays_bitwise():
    """host:4 maps the silo stack onto 4 pod slots with D == 1 — the
    placement must not perturb a single bit vs the single-device run."""
    proc = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][-1]
    out = json.loads(line[len("RESULT"):])
    assert out["bitwise"] and out["times"]
