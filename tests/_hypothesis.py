"""Import-or-stub hypothesis so that only the property tests skip when it
is not installed — the direct tests in the same modules still run.

Usage in a test module:

    from _hypothesis import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Stands in for ``strategies``: any strategy call returns None,
        which is fine because @given is a skip mark."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
