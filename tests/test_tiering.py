"""Tiering module tests (client partitioning by response latency)."""
import numpy as np
import pytest

from _hypothesis import given, settings, st  # property tests skip without hypothesis

from repro.core import tiering


def test_equal_partition():
    lat = np.arange(100)[::-1].astype(float)
    tm = tiering.assign_tiers(lat, 5)
    assert tm.n_tiers == 5
    assert all(len(m) == 20 for m in tm.members)


def test_monotone_in_latency():
    rng = np.random.default_rng(0)
    lat = rng.uniform(1, 30, 100)
    tm = tiering.assign_tiers(lat, 5)
    means = [lat[m].mean() for m in tm.members]
    assert all(a < b for a, b in zip(means, means[1:]))
    # every member of tier t is no slower than every member of tier t+1
    for t in range(4):
        assert lat[tm.members[t]].max() <= lat[tm.members[t + 1]].min() + 1e-9


@given(st.lists(st.floats(0.1, 100, allow_nan=False), min_size=10,
                max_size=60), st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_property_partition(lats, n_tiers):
    tm = tiering.assign_tiers(lats, n_tiers)
    all_ids = np.concatenate(tm.members)
    assert sorted(all_ids.tolist()) == list(range(len(lats)))
    assert (max(len(m) for m in tm.members) -
            min(len(m) for m in tm.members)) <= 1


def test_profile_bands():
    rng = np.random.default_rng(1)
    lat = tiering.profile_latencies(
        np.ones(100), ((0, 0), (0, 5), (6, 10), (11, 15), (20, 30)), rng)
    assert lat.min() >= 1.0 and lat.max() <= 31.0
    assert (lat > 20).sum() >= 15  # slowest band populated


def test_retier_preserves_count():
    tm = tiering.assign_tiers(np.arange(10.0), 2)
    tm2 = tiering.retier(tm, np.arange(10.0)[::-1].copy())
    assert tm2.n_tiers == 2
    # order flipped: old-fastest clients are now slowest
    assert set(tm2.members[1]) == set(tm.members[0])
