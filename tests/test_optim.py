"""Optimizer substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, sgd, cosine_schedule, global_norm


def test_sgd_quadratic_converges():
    opt = sgd(lr=0.1)
    params = {"x": jnp.asarray(5.0)}
    state = opt.init(params)
    for _ in range(100):
        grads = {"x": 2 * params["x"]}  # f = x^2
        params, state = opt.step(params, grads, state)
    assert abs(float(params["x"])) < 1e-4


def test_sgd_momentum_accelerates():
    def run(momentum, steps=30):
        opt = sgd(lr=0.02, momentum=momentum)
        p = {"x": jnp.asarray(5.0)}
        s = opt.init(p)
        for _ in range(steps):
            p, s = opt.step(p, {"x": 2 * p["x"]}, s)
        return abs(float(p["x"]))
    assert run(0.9) < run(0.0)


def test_adamw_first_step_is_lr_sized():
    opt = adamw(lr=1e-3)
    p = {"x": jnp.asarray(1.0)}
    s = opt.init(p)
    p2, _ = opt.step(p, {"x": jnp.asarray(0.5)}, s)
    # bias-corrected first Adam step ~= lr * sign(g)
    assert np.isclose(float(p["x"] - p2["x"]), 1e-3, rtol=1e-3)


def test_adamw_weight_decay():
    opt = adamw(lr=1e-2, weight_decay=0.1)
    p = {"x": jnp.asarray(10.0)}
    s = opt.init(p)
    p2, _ = opt.step(p, {"x": jnp.asarray(0.0)}, s)
    assert float(p2["x"]) < 10.0  # decays with zero gradient


def test_grad_clip():
    opt = adamw(lr=1.0, grad_clip=1.0)
    p = {"x": jnp.asarray(0.0), "y": jnp.asarray(0.0)}
    s = opt.init(p)
    _, s2 = opt.step(p, {"x": jnp.asarray(100.0), "y": jnp.asarray(0.0)}, s)
    # clipped grad enters the moment: |m| <= (1-b1) * clip
    assert float(jnp.abs(s2["m"]["x"])) <= 0.1 + 1e-6


def test_cosine_schedule_shape():
    fn = cosine_schedule(1.0, warmup=10, total=100)
    vals = [float(fn(jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert 0.0 < vals[0] <= 0.1 + 1e-6  # warmup starts nonzero: no no-op step
    assert np.isclose(vals[2], 1.0, atol=0.02)
    assert vals[3] < vals[2]
    assert np.isclose(vals[4], 0.1, atol=0.02)  # min_frac floor


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.ones((4,)) * 2}
    assert np.isclose(float(global_norm(t)), np.sqrt(3 + 16))
