"""End-to-end behaviour tests: the paper's claims at test scale, plus the
train/serve drivers (fault injection, resume, continuous batching)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import BaselineConfig, run_fedavg
from repro.core.fedat import FedATConfig, run_fedat
from repro.core.simulation import SimConfig, SimEnv


@pytest.fixture(scope="module")
def env():
    return SimEnv(SimConfig(n_clients=15, n_tiers=3, samples_per_client=30,
                            classes_per_client=2, image_hw=8,
                            clients_per_round=4, local_epochs=2,
                            n_unstable=2))


def test_time_to_accuracy_fedat_wins(env):
    """Figure 2 bar charts: wall-clock to a fixed target accuracy."""
    target = 0.30
    mf = run_fedat(env, FedATConfig(total_updates=40, eval_every=5))
    ma = run_fedavg(env, BaselineConfig(total_updates=40, eval_every=5))
    tf = mf.time_to_accuracy(target)
    ta = ma.time_to_accuracy(target)
    assert tf is not None
    if ta is not None:
        assert tf < ta


def test_train_driver_with_failures_and_resume(tmp_path):
    from repro.launch import train as train_mod
    ckpt = str(tmp_path / "ck")
    losses = train_mod.main([
        "--arch", "qwen2-7b", "--smoke", "--steps", "8",
        "--ckpt-dir", ckpt, "--ckpt-every", "4",
        "--inject-failure-rate", "0.2"])
    assert len(losses) >= 8
    # resume continues past the last checkpoint
    losses2 = train_mod.main([
        "--arch", "qwen2-7b", "--smoke", "--steps", "12",
        "--ckpt-dir", ckpt, "--resume"])
    assert len(losses2) >= 1


def test_train_driver_multipod_smoke(tmp_path):
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "granite-moe-3b-a800m", "--smoke", "--steps", "4",
        "--ckpt-dir", str(tmp_path / "ck2"), "--multi-pod",
        "--fedat-sync-every", "2"])
    assert len(losses) == 4
    assert np.isfinite(losses[-1])


def test_serve_driver_continuous_batching():
    from repro.launch import serve as serve_mod
    done = serve_mod.main(["--arch", "rwkv6-3b", "--smoke",
                           "--requests", "6", "--slots", "3",
                           "--prompt-len", "16", "--max-new", "8"])
    assert len(done) == 6
    assert all(len(r.out) >= 1 for r in done)


def test_serve_driver_swa_arch():
    from repro.launch import serve as serve_mod
    done = serve_mod.main(["--arch", "h2o-danube-3-4b", "--smoke",
                           "--requests", "3", "--slots", "3",
                           "--prompt-len", "12", "--max-new", "6"])
    assert len(done) == 3


def test_data_pipeline_deterministic():
    from repro.configs import registry
    from repro.configs.shapes import smoke_shape
    from repro.data.pipeline import TokenPipeline
    cfg = registry.get_smoke_config("qwen2-7b")
    p1 = TokenPipeline(cfg, smoke_shape("train"), seed=3)
    p2 = TokenPipeline(cfg, smoke_shape("train"), seed=3)
    np.testing.assert_array_equal(p1.batch(5)["tokens"], p2.batch(5)["tokens"])
    assert not np.array_equal(p1.batch(5)["tokens"], p1.batch(6)["tokens"])


def test_federated_data_non_iid_structure():
    from repro.data.federated import make_federated
    ds = make_federated(n_clients=20, classes_per_client=2, seed=1)
    for c in ds.clients:
        assert len(np.unique(c.y_train)) <= 2
    iid = make_federated(n_clients=5, classes_per_client=10,
                         samples_per_client=300, seed=1)
    assert len(np.unique(iid.clients[0].y_train)) >= 8
