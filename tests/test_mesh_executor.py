"""Client-sharded round executor on a device mesh (DESIGN.md
§Scale-mapping).

The mesh parity contract has two sides:

* **D == 1 is bitwise.**  A one-device host mesh builds the exact
  single-device steps — same trace keys, bit-identical trajectory — so
  turning the mesh machinery on cannot perturb the engine-parity oracle.
* **D > 1 is tolerance-pinned.**  A multi-device host mesh (forced via
  ``--xla_force_host_platform_device_count``, exercised in a subprocess so
  the device count doesn't leak into other tests) must reproduce the
  single-device trajectory within the tolerances pinned here: identical
  event times (the host-side event order never depends on the mesh),
  one-round parameters to ~1e-3, accuracies to a few percent after many
  chaotic Adam rounds.

Plus: the pad-to-axis-multiple validation surfaces (static for
``production``, build-time for ``host``), and the trace counters prove
meshing adds no shape-driven recompiles.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import api
from repro.core.fedat import FedATConfig, run_fedat
from repro.core.simulation import SimConfig, SimEnv
from repro.launch import mesh as mesh_mod

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BASE = dict(n_clients=16, n_tiers=3, samples_per_client=20,
             classes_per_client=2, image_hw=8, clients_per_round=8,
             local_epochs=1, n_unstable=2)


# ---------------------------------------------------------------------------
# mesh name grammar + static (spec-level) validation
# ---------------------------------------------------------------------------

def test_mesh_name_grammar_round_trips():
    for spec in (api.MeshSpec(), api.MeshSpec(kind="host"),
                 api.MeshSpec(kind="host", n_pods=2),
                 api.MeshSpec(kind="production"),
                 api.MeshSpec(kind="production", n_pods=2)):
        back = api.MeshSpec.from_name(spec.to_name())
        assert (back.kind, back.n_pods) == (spec.kind, spec.n_pods)
    assert mesh_mod.parse_mesh_name(None) == ("single", 1)
    assert mesh_mod.parse_mesh_name("host:4") == ("host", 4)
    for bad in ("cluster", "host:x", "host:0", "production:3"):
        with pytest.raises(ValueError):
            mesh_mod.parse_mesh_name(bad)


def test_mesh_spec_validation_errors():
    with pytest.raises(api.SpecError, match=r"mesh\.kind"):
        api.ExperimentSpec(mesh=api.MeshSpec(kind="cluster")).validate()
    with pytest.raises(api.SpecError, match=r"pod axis"):
        api.ExperimentSpec(mesh=api.MeshSpec(n_pods=2)).validate()
    with pytest.raises(api.SpecError, match=r"shard_tiers"):
        api.ExperimentSpec(
            mesh=api.MeshSpec(kind="host", shard_tiers=True)).validate()


def test_production_pad_validation_is_static():
    """The production data axis (16) is known without devices: a
    clients_per_round that doesn't divide fails at validate()."""
    spec = api.ExperimentSpec(mesh=api.MeshSpec(kind="production"))
    with pytest.raises(api.SpecError,
                       match=r"clients_per_round=10.*multiple of 16"):
        spec.validate()
    spec.tiers.clients_per_round = 32
    spec.validate()


def test_host_pad_validation_at_build_time():
    """With one local device the host data axis is 1, so any
    clients_per_round builds; the divisibility error for D > 1 is covered
    by the subprocess test below."""
    sc = SimConfig(**{**_BASE, "clients_per_round": 7}, mesh="host")
    assert SimEnv(sc).data_axis == len(__import__("jax").devices())


def test_no_mesh_env_ignores_ambient_mesh():
    """A no-mesh environment built inside a use_mesh() context must stay
    single-device: data_axis sizes from the env's own mesh, never the
    thread-local ambient one."""
    from repro.runtime import sharding as shd
    with shd.use_mesh(mesh_mod.make_host_mesh()):
        env = SimEnv(SimConfig(**_BASE))
    assert env.mesh is None and env.data_axis == 1
    assert not env.executor().shard_tiers


def test_resolve_mesh_host_pods_must_divide_devices():
    """The declarative path is strict: host:N with an indivisible device
    count fails loudly (make_host_mesh's silent fallback is only for
    direct callers like the trainer)."""
    n = len(__import__("jax").devices())
    with pytest.raises(ValueError, match="divisible"):
        mesh_mod.resolve_mesh(f"host:{n + 1}")


def test_mesh_is_part_of_provenance():
    base = api.ExperimentSpec()
    meshed = base.with_overrides({"mesh.kind": "host"})
    assert meshed.hash() != base.hash()
    assert meshed.env_hash() != base.env_hash()   # distinct cached envs


# ---------------------------------------------------------------------------
# D == 1: the mesh machinery is bitwise-invisible
# ---------------------------------------------------------------------------

@pytest.mark.skipif(len(__import__("jax").devices()) != 1,
                    reason="bitwise D==1 contract needs exactly 1 device")
def test_one_device_host_mesh_is_bitwise_single_device():
    env0 = SimEnv(SimConfig(**_BASE))
    env1 = SimEnv(SimConfig(**_BASE, mesh="host"))
    cfg = FedATConfig(total_updates=8, eval_every=4)
    m0, m1 = run_fedat(env0, cfg), run_fedat(env1, cfg)
    assert m0.times == m1.times and m0.acc == m1.acc
    assert m0.acc_var == m1.acc_var
    # same trace keys: the single-device steps, no "dataD" suffix
    assert set(env1.executor().trace_counts) \
        == set(env0.executor().trace_counts)
    assert all(len(k) == 3 for k in env1.executor().trace_counts)


@pytest.mark.skipif(len(__import__("jax").devices()) != 1,
                    reason="bitwise D==1 contract needs exactly 1 device")
@pytest.mark.parametrize("plane", ["stacked", "streaming"])
def test_one_device_host_mesh_bitwise_under_population(plane):
    """Population x mesh: the D == 1 bitwise contract holds under both
    indexed population planes too — a one-device host mesh reproduces
    the no-mesh trajectory exactly, whether the round data arrives via
    the resident gather or the streamed batch."""
    from repro.core.population import PopulationConfig
    pop = PopulationConfig(plane=plane, availability="bernoulli:0.9:20",
                           eval_clients=8, seed=3)
    base = {**_BASE, "n_clients": 64, "n_unstable": 6}
    env0 = SimEnv(SimConfig(**base, population=pop))
    env1 = SimEnv(SimConfig(**base, mesh="host", population=pop))
    cfg = FedATConfig(total_updates=8, eval_every=4)
    m0, m1 = run_fedat(env0, cfg), run_fedat(env1, cfg)
    assert m0.times == m1.times and m0.acc == m1.acc
    assert m0.acc_var == m1.acc_var
    assert set(env1.executor().trace_counts) \
        == set(env0.executor().trace_counts)
    want_stream = plane == "streaming"
    assert all(("stream" in k) == want_stream
               for k in env1.executor().trace_counts)


# ---------------------------------------------------------------------------
# D > 1: forced multi-device host mesh in a subprocess
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, numpy as np
    from repro import api
    from repro.core.fedat import FedATConfig, run_fedat
    from repro.core.simulation import SimConfig, SimEnv

    base = dict(n_clients=16, n_tiers=3, samples_per_client=20,
                classes_per_client=2, image_hw=8, clients_per_round=8,
                local_epochs=1, n_unstable=2)
    env0 = SimEnv(SimConfig(**base))
    env1 = SimEnv(SimConfig(**base, mesh="host"))
    out = {"n_devices": len(jax.devices()), "data_axis": env1.data_axis}

    # one fused round, executor-level: tight numerical agreement
    from repro.compress import transport
    from repro.core import aggregation
    import jax.numpy as jnp
    codec = transport.get_codec("polyline:4")
    M = env0.tm.n_tiers
    cw = aggregation.uniform_weights(M)
    args = lambda env: (jax.tree.map(jnp.array, env.params0),
                        jax.tree.map(lambda l: jnp.stack([l] * M),
                                     env.params0))
    ids = np.arange(8, dtype=np.int32)
    w0, _ = env0.executor().fedat_round(*args(env0), 0, ids, 7, codec=codec,
                                        use_prox=True, cross_weights=cw)
    w1, _ = env1.executor().fedat_round(*args(env1), 0, ids, 7, codec=codec,
                                        use_prox=True, cross_weights=cw)
    out["round_maxdiff"] = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(w0), jax.tree.leaves(w1)))

    # engine-level trajectory: past the earliest dropouts (uniform(50,400))
    cfg = FedATConfig(total_updates=30, eval_every=6)
    m0, m1 = run_fedat(env0, cfg), run_fedat(env1, cfg)
    out["times_equal"] = m0.times == m1.times
    out["acc_maxdiff"] = max(abs(a - b) for a, b in zip(m0.acc, m1.acc))
    out["keys0"] = sorted(map(str, env0.executor().trace_counts))
    out["keys1"] = sorted(map(str, env1.executor().trace_counts))
    # no shape-driven recompiles: dropouts shrank samples, yet each
    # sharded step traced exactly once
    out["trace_counts1"] = list(env1.executor().trace_counts.values())

    # pad-to-axis-multiple build error under the real 4-device mesh
    try:
        api.get_env(api.ExperimentSpec(
            data=api.DataSpec(n_clients=16, samples_per_client=20,
                              image_hw=8),
            tiers=api.TierSpec(n_tiers=3, clients_per_round=10,
                               n_unstable=2),
            mesh=api.MeshSpec(kind="host")))
        out["pad_error"] = None
    except api.SpecError as e:
        out["pad_error"] = str(e)
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def mesh4():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=900,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_sharded_matches_single_device_within_tolerance(mesh4):
    assert mesh4["n_devices"] == 4 and mesh4["data_axis"] == 4
    # host-side event order never depends on the mesh: times are bitwise
    assert mesh4["times_equal"]
    # pinned tolerances: one fused round agrees to ~1e-3 (psum
    # reassociation + shard-local vmap scheduling only); a 30-update
    # chaotic Adam trajectory stays within a few percent of accuracy
    assert mesh4["round_maxdiff"] < 2e-3, mesh4["round_maxdiff"]
    assert mesh4["acc_maxdiff"] < 0.1, mesh4["acc_maxdiff"]


def test_sharded_steps_have_distinct_keys_and_no_retraces(mesh4):
    assert all("data4" in k for k in mesh4["keys1"])
    assert not any("data4" in k for k in mesh4["keys0"])
    # meshing adds no recompiles: one trace per configuration, across the
    # dropout-shrunken samples of a 30-update run
    assert all(c == 1 for c in mesh4["trace_counts1"])


def test_host_pad_validation_under_forced_devices(mesh4):
    assert mesh4["pad_error"] is not None
    assert "multiple of 4" in mesh4["pad_error"]
