"""Declarative ExperimentSpec API (repro/api/): serialization round-trip,
registry error surfaces, spec-driven vs legacy-wrapper bitwise parity,
re-tiering wiring, env caching, and the CLI sweep path."""
import json

import numpy as np
import pytest

from repro import api
from repro.api import cli
from repro.core.baselines import BaselineConfig, run_fedavg, run_fedasync, \
    run_tifl
from repro.core.fedat import FedATConfig, run_fedat
from repro.core.simulation import SimEnv


def _small_spec(**overrides):
    """One tiny scenario shared by every test in this module so the env
    cache materializes a single environment."""
    spec = api.ExperimentSpec().with_overrides({
        "data.n_clients": 12, "data.samples_per_client": 20,
        "data.image_hw": 8, "tiers.n_tiers": 3,
        "tiers.clients_per_round": 4, "tiers.n_unstable": 2,
        "engine.local_epochs": 1, "engine.total_updates": 8,
        "engine.eval_every": 4})
    return spec.with_overrides(overrides) if overrides else spec


# ---------------------------------------------------------------------------
# serialization + provenance
# ---------------------------------------------------------------------------

def test_json_round_trip_golden():
    spec = api.ExperimentSpec()
    d = spec.to_dict()
    back = api.ExperimentSpec.from_json(spec.to_json())
    assert back.to_dict() == d
    assert back == spec
    assert back.hash() == spec.hash()
    # golden hash: the canonical serialization of the default (paper) spec
    # is part of the provenance contract — changing any default field,
    # field name, or the canonicalization breaks attribution of archived
    # bench results and must be deliberate (bump SPEC_VERSION).
    # v7 added the topology section (hierarchical geo-distributed
    # federation) and population.profile (device-class presets;
    # re-pinned from "2a8635d9e5d9" deliberately); v6 added the
    # population section (million-client population plane; re-pinned
    # from "f556a6283a5b" deliberately); v5 added the faults section
    # (deterministic fault plane); v4 added data.attention_backend
    # (kernel-layer attention vs. the reference oracle); v3 replaced
    # data.task with the registry-backed data.model (+ token knobs);
    # v2 added the mesh section.
    assert d["spec_version"] == api.SPEC_VERSION == 7
    assert spec.hash() == "60fd95ec9d49"


def test_old_spec_documents_still_parse():
    """Version-1/2/3/4/5/6 documents (no topology section or
    population.profile pre-v7, no population section pre-v6, no faults
    section pre-v5, data.task enum pre-v3, no attention_backend pre-v4,
    v1 additionally pre-mesh) parse to the same spec under
    SPEC_VERSION 7; unknown versions still fail with the supported
    range.  (Full migration coverage lives in
    tests/test_model_registry.py.)"""
    spec = api.ExperimentSpec()
    d = spec.to_dict()
    d.pop("topology")
    d["population"].pop("profile")
    d["spec_version"] = 6
    back = api.ExperimentSpec.from_dict(d)
    assert back == spec
    # v6 docs get the inert topology plane and the 'none' profile exactly
    assert back.topology == api.TopologySpec()
    assert back.topology.to_config() is None
    assert back.population.profile == "none"
    d.pop("population")
    d["spec_version"] = 5
    back = api.ExperimentSpec.from_dict(d)
    assert back == spec
    # v5 docs get the default section = the legacy stacked plane exactly
    assert back.population == api.PopulationSpec()
    assert back.to_sim_config().population is None
    d.pop("faults")
    d["spec_version"] = 4
    back = api.ExperimentSpec.from_dict(d)
    assert back == spec
    assert back.faults == api.FaultSpec()  # v4 docs get the zero-fault plane
    d["data"].pop("attention_backend")
    d["spec_version"] = 3
    back = api.ExperimentSpec.from_dict(d)
    assert back == spec
    assert back.data.attention_backend == "auto"  # v3 docs get the default
    for k in ("model", "vocab_size", "seq_len"):
        d["data"].pop(k)
    d["data"]["task"] = "image"
    d["spec_version"] = 2
    back = api.ExperimentSpec.from_dict(d)
    assert back == spec
    assert back.data.model == "cnn"       # task shim
    d.pop("mesh")
    d["spec_version"] = 1
    back = api.ExperimentSpec.from_dict(d)
    assert back == spec
    assert back.mesh == api.MeshSpec()    # single-device default
    d["spec_version"] = 99
    with pytest.raises(api.SpecError, match=r"spec_version 99"):
        api.ExperimentSpec.from_dict(d)


def test_hash_tracks_content_not_formatting():
    spec = _small_spec()
    # same content through a JSON round trip -> same hash
    assert api.ExperimentSpec.from_json(spec.to_json()).hash() == spec.hash()
    # any field change -> different hash
    assert spec.with_overrides({"engine.seed": 1}).hash() != spec.hash()
    assert spec.with_overrides(
        {"transport.codec": "quantize8"}).hash() != spec.hash()
    # env hash ignores engine-plane knobs but tracks the scenario
    assert spec.with_overrides(
        {"engine.total_updates": 99}).env_hash() == spec.env_hash()
    assert spec.with_overrides(
        {"data.seed": 7}).env_hash() != spec.env_hash()


# ---------------------------------------------------------------------------
# actionable validation errors
# ---------------------------------------------------------------------------

def test_unknown_field_rejected_with_valid_list():
    with pytest.raises(api.SpecError, match=r"n_cleints.*n_clients"):
        api.ExperimentSpec.from_dict({"data": {"n_cleints": 3}})
    with pytest.raises(api.SpecError, match=r"unknown section.*datas"):
        api.ExperimentSpec.from_dict({"datas": {}})
    with pytest.raises(api.SpecError, match=r"unknown spec field"):
        _small_spec().with_overrides({"tiers.n_teirs": 3})


def test_population_section_validation_errors():
    with pytest.raises(api.SpecError, match=r"population\.plane.*stream"):
        _small_spec(**{"population.plane": "lazy"}).validate()
    with pytest.raises(api.SpecError,
                       match=r"population\.availability.*bernoulli"):
        _small_spec(**{"population.availability": "poisson:3"}).validate()
    with pytest.raises(api.SpecError,
                       match=r"probability must be in \[0, 1\]"):
        _small_spec(**{"population.completion": "bernoulli:1.5"}).validate()
    with pytest.raises(api.SpecError,
                       match=r"population\.responsiveness.*lognormal"):
        _small_spec(**{"population.responsiveness": "gamma:2"}).validate()
    with pytest.raises(api.SpecError, match=r"population\.eval_clients"):
        _small_spec(**{"population.eval_clients": 99}).validate()


def test_population_section_in_env_hash():
    """The population scenario re-materializes the environment: the env
    cache key must track it (and ignore it when inert)."""
    spec = _small_spec()
    assert spec.with_overrides(
        {"population.plane": "streaming"}).env_hash() != spec.env_hash()
    assert spec.with_overrides(
        {"population.availability": "bernoulli:0.9"}).env_hash() \
        != spec.env_hash()
    # seed alone is inert config-wise but still hashes (it seeds streams)
    assert spec.with_overrides(
        {"population.seed": 1}).env_hash() != spec.env_hash()


def test_unknown_registry_names_list_whats_registered():
    with pytest.raises(api.SpecError, match=r"fedsgd.*registered.*fedat"):
        _small_spec(**{"strategy.name": "fedsgd"}).validate()
    with pytest.raises(api.SpecError, match=r"zstd.*registered.*quantize"):
        _small_spec(**{"transport.codec": "zstd"}).validate()
    with pytest.raises(api.SpecError, match=r"partitioner.*dirichlet"):
        _small_spec(**{"data.partitioner": "zipf"}).validate()
    with pytest.raises(api.SpecError, match=r"does not accept.*accepted"):
        _small_spec(**{"strategy.kwargs.bogus": 1}).validate()
    with pytest.raises(api.SpecError, match=r"transport\.codec"):
        _small_spec(**{"strategy.kwargs.codec": "none"}).validate()


# ---------------------------------------------------------------------------
# spec-driven runs == legacy wrappers, bitwise
# ---------------------------------------------------------------------------

def _assert_bitwise(m_spec, m_legacy):
    assert m_spec.rounds == m_legacy.rounds
    assert m_spec.times == m_legacy.times
    assert m_spec.acc == m_legacy.acc
    assert m_spec.acc_var == m_legacy.acc_var
    assert m_spec.bytes_up == m_legacy.bytes_up
    assert m_spec.bytes_down == m_legacy.bytes_down


@pytest.fixture(scope="module")
def legacy_env():
    """An environment built outside the api cache, as seed-era callers do."""
    return SimEnv(_small_spec().to_sim_config())


def test_fedat_spec_matches_legacy_wrapper(legacy_env):
    fc = FedATConfig(total_updates=8, eval_every=4)
    m_legacy = run_fedat(legacy_env, fc)
    m_spec = api.run_spec(_small_spec()).metrics
    _assert_bitwise(m_spec, m_legacy)


@pytest.mark.parametrize("name,kwargs", [
    ("fedavg", {}),
    ("tifl", {}),
    ("fedasync", {"alpha": 0.6, "staleness_exp": 0.5}),
])
def test_baseline_spec_matches_legacy_wrapper(legacy_env, name, kwargs):
    bc = BaselineConfig(total_updates=8, eval_every=4)
    fn = {"fedavg": run_fedavg, "tifl": run_tifl,
          "fedasync": run_fedasync}[name]
    m_legacy = fn(legacy_env, bc)
    spec = _small_spec(**{"strategy.name": name,
                          "strategy.kwargs": kwargs})
    m_spec = api.run_spec(spec).metrics
    _assert_bitwise(m_spec, m_legacy)


def test_spec_echo_is_truthful(legacy_env):
    """The shim's Result-side spec reflects the env it actually ran on."""
    spec = api.ExperimentSpec.from_sim_config(legacy_env.sc)
    assert spec.data.n_clients == legacy_env.sc.n_clients
    assert spec.to_sim_config() == legacy_env.sc


# ---------------------------------------------------------------------------
# env cache + run handle
# ---------------------------------------------------------------------------

def test_env_cache_shared_across_strategy_and_codec_plane():
    e1 = api.get_env(_small_spec())
    e2 = api.get_env(_small_spec(**{"strategy.name": "fedavg",
                                    "transport.codec": "quantize8",
                                    "engine.total_updates": 3}))
    assert e1 is e2
    e3 = api.get_env(_small_spec(**{"data.seed": 5}))
    assert e3 is not e1


def test_streaming_eval_callback():
    points = []
    res = api.run_spec(_small_spec(), on_eval=points.append)
    assert len(points) == len(res.metrics.acc) >= 1
    assert points[0]["acc"] == res.metrics.acc[0]
    assert points[-1]["round"] == res.metrics.rounds[-1]


# ---------------------------------------------------------------------------
# re-tiering (tiers.retier_every wires core/tiering.retier into the loop)
# ---------------------------------------------------------------------------

def test_retier_every_changes_tier_membership():
    run = api.build(_small_spec(**{"tiers.retier_every": 2,
                                   "tiers.retier_drift": 0.5}))
    env, tm0 = run.env, run.env.tm
    changed = []
    orig = SimEnv.retier
    env.retier = lambda rng, drift=0.2: changed.append(
        orig(env, rng, drift))
    try:
        res = run.run()
    finally:
        del env.retier
    assert len(changed) >= 3          # fired every 2 of 8 updates
    assert any(changed)               # membership actually moved
    assert env.tm is tm0              # restored: cached env reproducible
    # and the run is still a full, finite trajectory
    assert np.isfinite(res.metrics.acc).all()


def test_retier_runs_are_deterministic():
    spec = _small_spec(**{"tiers.retier_every": 2})
    m1 = api.run_spec(spec).metrics
    m2 = api.run_spec(spec).metrics
    assert m1.times == m2.times and m1.acc == m2.acc


# ---------------------------------------------------------------------------
# sweep + CLI (acceptance: 2x2 strategy x codec from one invocation)
# ---------------------------------------------------------------------------

def test_sweep_grid_tags_and_order():
    results = api.sweep(
        _small_spec(**{"engine.total_updates": 2, "engine.eval_every": 2}),
        {"strategy.name": ["fedat", "fedavg"],
         "transport.codec": ["none", "quantize8"]})
    assert [r.tag for r in results] == [
        "strategy.name=fedat,transport.codec=none",
        "strategy.name=fedat,transport.codec=quantize8",
        "strategy.name=fedavg,transport.codec=none",
        "strategy.name=fedavg,transport.codec=quantize8"]
    assert all(len(r.metrics.acc) >= 1 for r in results)
    # compression bites on both strategies
    assert results[1].metrics.bytes_up[-1] < results[0].metrics.bytes_up[-1]
    assert results[3].metrics.bytes_up[-1] < results[2].metrics.bytes_up[-1]


def test_sweep_validates_before_running():
    with pytest.raises(api.SpecError):
        api.sweep(_small_spec(), {"strategy.name": ["fedat", "fedsgd"]})
    with pytest.raises(api.SpecError):
        api.sweep(_small_spec(), {})


def test_cli_2x2_sweep_single_invocation(tmp_path):
    spec_path = tmp_path / "exp.json"
    out_path = tmp_path / "results.json"
    spec_path.write_text(_small_spec(
        **{"engine.total_updates": 2, "engine.eval_every": 2}).to_json())
    results = cli.main([
        "--spec", str(spec_path),
        "--sweep", "strategy.name=fedat,fedavg",
        "--sweep", "transport.codec=none,quantize8",
        "--out", str(out_path)])
    assert len(results) == 4
    doc = json.loads(out_path.read_text())
    assert len(doc["runs"]) == 4
    hashes = {r["spec_hash"] for r in doc["runs"]}
    assert len(hashes) == 4           # four distinct attributable configs
    for rec in doc["runs"]:
        assert rec["trajectory"]["acc"]
        assert api.ExperimentSpec.from_dict(rec["spec"]).hash() \
            == rec["spec_hash"]


# ---------------------------------------------------------------------------
# checkpointing (Run.run(checkpoint_dir=...) <-> build(resume_from=...))
# ---------------------------------------------------------------------------

def test_checkpoint_save_and_resume(tmp_path):
    ck = str(tmp_path / "ck")
    spec = _small_spec()
    res = api.build(spec).run(checkpoint_dir=ck)
    doc = json.loads((tmp_path / "ck" / "spec.json").read_text())
    assert doc["spec_hash"] == res.spec_hash
    assert doc["step"] == spec.engine.total_updates
    assert api.ExperimentSpec.from_dict(doc["spec"]) == spec

    run = api.build(spec, resume_from=ck)
    assert run.initial_params is not None
    params0_before = run.env.params0      # the env's own seeded init
    assert run.initial_params is not params0_before
    res2 = run.run()
    assert np.isfinite(res2.metrics.acc).all()
    # the *original* params0 object is back after the run (the cached env
    # stays reproducible; would fail if Run.run's finally-restore broke)
    assert run.env.params0 is params0_before


def test_checkpoint_resume_spec_hash_mismatch(tmp_path):
    ck = str(tmp_path / "ck")
    api.build(_small_spec()).run(checkpoint_dir=ck)
    other = _small_spec(**{"engine.seed": 9})
    with pytest.raises(api.SpecError, match=r"written by spec .* current "
                                            r"spec hashes to"):
        api.build(other, resume_from=ck)
    with pytest.raises(api.SpecError, match=r"no spec\.json"):
        api.build(_small_spec(), resume_from=str(tmp_path / "nope"))
    # a corrupt sidecar (e.g. killed mid-write) is still a SpecError
    (tmp_path / "ck" / "spec.json").write_text("{truncated")
    with pytest.raises(api.SpecError, match="unreadable spec.json"):
        api.build(_small_spec(), resume_from=ck)


def test_checkpoint_dir_reuse_holds_exactly_one_spec(tmp_path):
    """A reused directory holds exactly the sidecar's checkpoint: stale
    steps from a previous spec are cleared on save (a higher-numbered
    stale step would otherwise be restored as 'latest', or trip the
    manager's keep-last-k GC into deleting the fresh step), and resume
    restores the step the sidecar stamps."""
    import jax
    import jax.numpy as jnp
    ck = str(tmp_path / "ck")
    spec_a = _small_spec()                           # total_updates=8
    spec_b = _small_spec(**{"engine.seed": 5, "engine.total_updates": 4})
    api.build(spec_a).run(checkpoint_dir=ck)         # writes step_8
    api.build(spec_b).run(checkpoint_dir=ck)         # clears it, writes step_4
    steps = sorted(int(p.name[5:]) for p in (tmp_path / "ck").iterdir()
                   if p.name.startswith("step_"))
    assert steps == [4]                              # A's step_8 is gone
    doc = json.loads((tmp_path / "ck" / "spec.json").read_text())
    assert doc["step"] == 4 and doc["spec_hash"] == spec_b.hash()
    run = api.build(spec_b, resume_from=ck)
    from repro.checkpoint import CheckpointManager
    env = api.get_env(spec_b)
    want, got_step = CheckpointManager(ck).restore(
        like={"params": env.params0}, step=4)
    assert got_step == 4
    assert all(bool(jnp.array_equal(a, b)) for a, b in
               zip(jax.tree.leaves(run.initial_params),
                   jax.tree.leaves(want["params"])))


def test_cli_checkpoint_roundtrip(tmp_path):
    ck = str(tmp_path / "cli_ck")
    args = ["--set", "data.n_clients=12", "--set", "data.image_hw=8",
            "--set", "data.samples_per_client=20",
            "--set", "tiers.n_tiers=3", "--set", "tiers.clients_per_round=4",
            "--set", "tiers.n_unstable=2", "--set", "engine.local_epochs=1",
            "--set", "engine.total_updates=2", "--set", "engine.eval_every=2"]
    cli.main(args + ["--checkpoint-dir", ck])
    results = cli.main(args + ["--resume-from", ck])
    assert len(results) == 1 and results[0].metrics.acc
    with pytest.raises(SystemExit):  # argparse error (exit code 2)
        cli.main(args + ["--checkpoint-dir", ck,
                         "--sweep", "strategy.name=fedat,fedavg"])


def test_cli_set_overrides_and_spec_errors(tmp_path, capsys):
    results = cli.main(["--set", "data.n_clients=12",
                        "--set", "data.samples_per_client=20",
                        "--set", "data.image_hw=8",
                        "--set", "tiers.n_tiers=3",
                        "--set", "tiers.clients_per_round=4",
                        "--set", "tiers.n_unstable=2",
                        "--set", "engine.local_epochs=1",
                        "--set", "engine.total_updates=2",
                        "--set", "engine.eval_every=2"])
    assert len(results) == 1 and results[0].metrics.acc
    with pytest.raises(SystemExit, match="spec error"):
        cli.main(["--set", "strategy.name=fedsgd"])
    with pytest.raises(SystemExit, match="PATH=VALUE"):
        cli.main(["--set", "strategy.name"])
