"""The paper's convergence bounds (Theorems 5.1/5.2) as executable
contracts, cross-checked against an actual strongly-convex FedAT run."""
import numpy as np
import pytest

from _hypothesis import given, settings, st  # property tests skip without hypothesis

from repro.core import theory
from repro.core.theory import Regime


def test_contraction_requires_small_eta():
    r = Regime(mu=0.5, eta=0.1, sigma=1.0)
    assert theory.contraction_factor(r, B=1.0) == 1 - 2 * 0.5 * 0.1
    assert theory.max_stable_eta(r, 1.0) == 1.0


def test_convex_bound_monotone_decreasing_to_floor():
    # small floor regime (tight local solves, small tier): bound decreases
    r = Regime(gamma=0.1, c=2)
    bs = [theory.convex_bound(r, 0.5, t, f0_gap=1.0) for t in (0, 10, 100,
                                                               2000)]
    assert bs[0] == 1.0
    assert all(a >= b - 1e-12 for a, b in zip(bs, bs[1:]))
    floor = theory.error_floor(r, 0.5) / (1 - theory.contraction_factor(
        r, 0.5))
    assert abs(bs[-1] - floor) < 1e-3
    # loose local solves (paper's gamma-inexactness) raise the floor above
    # the initial gap: the bound then *rises* toward it — also per theorem
    r2 = Regime(gamma=0.5, c=10)
    floor2 = theory.error_floor(r2, 0.5) / (1 - theory.contraction_factor(
        r2, 0.5))
    assert floor2 > 1.0


def test_unstable_eta_gives_inf():
    r = Regime(mu=1.0, eta=10.0)
    assert theory.convex_bound(r, 1.0, 10, 1.0) == np.inf


@given(st.lists(st.integers(1, 100), min_size=2, max_size=6))
@settings(max_examples=30, deadline=None)
def test_eq3_weights_form_simplex(counts):
    ws = [theory.eq3_weight(counts, m) for m in range(len(counts))]
    assert all(w >= 0 for w in ws)
    assert abs(sum(ws) - 1.0) < 1e-9


def test_floor_scales_with_inexactness_and_tier_size():
    r = Regime()
    assert theory.error_floor(r, 1.0) > theory.error_floor(r, 0.5)
    r2 = Regime(gamma=1.0)
    assert theory.error_floor(r2, 0.5) > theory.error_floor(r, 0.5)
    r3 = Regime(c=20)
    assert theory.error_floor(r3, 0.5) > theory.error_floor(r, 0.5)


def test_nonconvex_bound_tradeoff_in_eta():
    """Theorem 5.2: small eta blows up the first term, large eta the
    second — an interior optimum exists."""
    r = lambda eta: Regime(eta=eta)
    T, gap, B = 100, 1.0, 0.5
    etas = [1e-4, 1e-2, 1.0]
    vals = [theory.nonconvex_bound(r(e), B, T, gap) for e in etas]
    assert vals[1] < vals[0] and vals[1] < vals[2]


def test_empirical_convex_run_respects_bound_shape():
    """A quadratic federated objective run with FedAT-style weighted
    averaging contracts geometrically to a floor, as Theorem 5.1 says."""
    rng = np.random.default_rng(0)
    M, d = 3, 8
    # per-tier quadratic minima (heterogeneous == non-IID)
    mins = rng.normal(0, 1.0, (M, d))
    mu = 1.0  # f_m(w) = mu/2 |w - w_m|^2
    eta = 0.2
    counts = np.array([4.0, 2.0, 1.0])
    w_tiers = np.zeros((M, d))
    w = np.zeros(d)
    f_star_gap = []
    f = lambda w_: np.mean([0.5 * mu * np.sum((w_ - m) ** 2) for m in mins])
    w_opt = mins.mean(0)
    for t in range(200):
        m = t % M
        # tier does a local gradient step from the global model (inexact)
        w_tiers[m] = w - eta * mu * (w - mins[m])
        weights = counts[::-1] / counts.sum()
        w = (weights[:, None] * w_tiers).sum(0)
        f_star_gap.append(f(w) - f(w_opt))
    # geometric-ish decay then a floor strictly above zero (heterogeneity:
    # Eq. 3's reversed weights bias w away from the uniform optimum, the
    # empirical face of Theorem 5.1's additive floor)
    assert f_star_gap[-1] < 0.3 * f_star_gap[0]
    assert f_star_gap[-1] > 0.0
    late = f_star_gap[-50:]
    assert max(late) - min(late) < 0.05 * f_star_gap[0]  # settled at floor
