"""Per-architecture smoke tests: reduced configs, one train + serve step on
CPU, asserting shapes and finiteness; decode == teacher-forcing consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.shapes import SHAPES, applicable
from repro.models import lm

ARCHS = registry.ARCH_IDS


def _train_batch(cfg, B=4, S=128, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.family == "vlm":
        np_ = 16
        return {"patch_embeds": jax.random.normal(key, (B, np_, cfg.d_model)),
                "tokens": jax.random.randint(key, (B, S - np_), 0,
                                             cfg.vocab_size)}
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model)),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
                "mask": jnp.zeros((B, S), bool).at[:, ::4].set(True)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = registry.get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), tp=1)
    batch = _train_batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: lm.loss_fn(cfg, p, b, 1))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_gradients_flow(arch):
    cfg = registry.get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), tp=1)
    batch = _train_batch(cfg, B=2, S=64)
    grads = jax.jit(jax.grad(
        lambda p: lm.loss_fn(cfg, p, batch, 1)[0]))(params)
    gnorms = [float(jnp.linalg.norm(g)) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms)
    # the input-side table must receive gradient (embeddings, or the
    # frontend projection for the frame-stub audio arch)
    probe = grads["frontend_proj"] if cfg.family == "audio" else \
        grads["embed"]
    assert float(jnp.abs(probe).max()) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if registry.get_config(a).is_decoder])
def test_decode_matches_teacher_forcing(arch):
    cfg = registry.get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(2), tp=1)
    B, S = 2, 64
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                              cfg.vocab_size)

    def mk(s):
        if cfg.family == "vlm":
            return {"patch_embeds": jax.random.normal(
                jax.random.PRNGKey(7), (B, 8, cfg.d_model)),
                "tokens": toks[:, :s - 8]}
        return {"tokens": toks[:, :s]}

    cache = lm.init_cache(cfg, B, S + 1, 1, dtype=jnp.float32)
    _, cache = jax.jit(lambda p, b, c: lm.serve_prefill(cfg, p, b, 1, c))(
        params, mk(S), cache)
    nxt = toks[:, S - 8] if cfg.family == "vlm" else toks[:, S]
    la, _ = jax.jit(lambda p, t, po, c: lm.serve_step(cfg, p, t, po, 1, c))(
        params, nxt, jnp.asarray(S, jnp.int32), cache)
    cache2 = lm.init_cache(cfg, B, S + 1, 1, dtype=jnp.float32)
    lb, _ = jax.jit(lambda p, b, c: lm.serve_prefill(cfg, p, b, 1, c))(
        params, mk(S + 1), cache2)
    assert float(jnp.max(jnp.abs(la - lb))) < 2e-3


#: archs with no published size to check against (CPU-sized test models);
#: every *real* arch must appear in the advertised dict below — a new
#: production arch missing from it is a hard KeyError, not a skip
CPU_SIZED_ARCHS = {"tiny-lm", "tiny-lm-long"}


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_order_of_magnitude(arch):
    """Full configs should be within 2x of their advertised size."""
    if arch in CPU_SIZED_ARCHS:
        pytest.skip(f"{arch} is a CPU-sized arch with no published size")
    cfg = registry.get_config(arch)
    advertised = {
        "zamba2-2.7b": 2.7e9, "paligemma-3b": 2.5e9,  # text tower only
        "h2o-danube-3-4b": 4e9, "qwen2-7b": 7e9, "minitron-8b": 8e9,
        "qwen1.5-110b": 110e9, "granite-moe-3b-a800m": 3.3e9,
        "deepseek-moe-16b": 16e9, "rwkv6-3b": 3e9, "hubert-xlarge": 1e9,
    }[arch]
    n = cfg.param_count()
    assert 0.4 * advertised < n < 2.2 * advertised, (n, advertised)


@pytest.mark.parametrize("arch", ARCHS)
def test_applicability_matrix(arch):
    cfg = registry.get_config(arch)
    cells = [s for s in SHAPES.values() if applicable(cfg, s)]
    assert any(s.kind == "train" for s in cells)
    if not cfg.is_decoder:
        assert all(s.kind != "decode" for s in cells)
    if not cfg.sub_quadratic:
        assert all(s.name != "long_500k" for s in cells)


def test_swa_cache_is_ring_buffer():
    cfg = registry.get_smoke_config("h2o-danube-3-4b")
    assert cfg.swa_window == 64
    cache = lm.init_cache(cfg, 2, 512, 1)
    assert cache.k.shape[2] == cfg.swa_window  # (L, B, W, kv, hd)


def test_moe_aux_loss_nonzero():
    cfg = registry.get_smoke_config("deepseek-moe-16b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0), tp=1)
    batch = _train_batch(cfg, B=2, S=64)
    _, metrics = jax.jit(lambda p, b: lm.loss_fn(cfg, p, b, 1))(params, batch)
    assert float(metrics["aux_loss"]) > 0
