"""FedAT protocol tests: the simulation-level algorithm (Algorithm 1) and
its reductions/ablations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import BaselineConfig, run_fedavg, run_fedasync, \
    run_tifl
from repro.core.fedat import FedATConfig, fake_polyline, measure_ratio, \
    run_fedat
from repro.core.simulation import SimConfig, SimEnv


@pytest.fixture(scope="module")
def env():
    return SimEnv(SimConfig(n_clients=15, n_tiers=3, samples_per_client=30,
                            classes_per_client=2, image_hw=8,
                            clients_per_round=4, local_epochs=2,
                            n_unstable=2))


def test_fedat_runs_and_improves(env):
    m = run_fedat(env, FedATConfig(total_updates=30, eval_every=10))
    assert len(m.acc) >= 2
    assert m.acc[-1] > 0.15  # better than chance (10 classes)
    assert m.bytes_up[-1] > 0 and m.bytes_down[-1] > 0


def test_fedat_wallclock_beats_fedavg(env):
    """Definition 3.1 criterion 1: convergence speed in simulated time."""
    mf = run_fedat(env, FedATConfig(total_updates=30, eval_every=30))
    ma = run_fedavg(env, BaselineConfig(total_updates=30, eval_every=30))
    # same number of global updates, but FedAT never waits for stragglers
    assert mf.times[-1] < ma.times[-1] / 2


def test_compression_reduces_bytes(env):
    m_c = run_fedat(env, FedATConfig(total_updates=12, eval_every=12,
                                     precision=4))
    m_u = run_fedat(env, FedATConfig(total_updates=12, eval_every=12,
                                     precision=None))
    assert m_c.bytes_up[-1] < 0.8 * m_u.bytes_up[-1]


def test_fake_polyline_is_codec_round():
    x = {"w": jnp.asarray([0.123456, -2.987654])}
    y = fake_polyline(x, 4)
    np.testing.assert_allclose(np.asarray(y["w"]), [0.1235, -2.9877],
                               atol=1e-6)


def test_measured_ratio_below_one():
    params = {"w": jnp.asarray(
        np.random.default_rng(0).normal(0, 0.05, 2048), jnp.float32)}
    assert measure_ratio(params, 4) < 0.9
    assert measure_ratio(params, None) == 1.0


def test_baselines_run(env):
    bc = BaselineConfig(total_updates=10, eval_every=10)
    for fn in (run_fedavg, run_tifl, run_fedasync):
        m = fn(env, bc)
        assert len(m.acc) >= 1
        assert np.isfinite(m.acc[-1])


def test_weighted_beats_uniform_eventually(env):
    """Fig. 6 ablation runs; both modes must be functional."""
    mw = run_fedat(env, FedATConfig(total_updates=25, eval_every=25,
                                    weighted=True))
    mu = run_fedat(env, FedATConfig(total_updates=25, eval_every=25,
                                    weighted=False))
    assert np.isfinite(mw.acc[-1]) and np.isfinite(mu.acc[-1])


def test_dropout_clients_leave(env):
    alive_late = env.alive(1e9)
    assert alive_late.sum() == env.sc.n_clients - env.sc.n_unstable
