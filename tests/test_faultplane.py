"""Deterministic fault plane (core/faults.py + spec faults section):
churn windows, tier blackouts, the update validation gate, and the
elastic Eq. 3 renormalization it rides on.  The zero-fault side of the
contract — specs with the default faults section are bitwise the
pre-fault-plane engine — is pinned by tests/test_engine_parity.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import aggregation
from repro.core import faults
from repro.core import steps as fl_steps
from repro.core.simulation import SimEnv
from repro.runtime import elastic


def _spec(**faults_kwargs):
    """Small 2-tier scenario; faults_kwargs populate the faults section."""
    return api.ExperimentSpec(
        data=api.DataSpec(n_clients=8, samples_per_client=24, image_hw=8),
        tiers=api.TierSpec(n_tiers=2, clients_per_round=2, n_unstable=0),
        engine=api.EngineSpec(total_updates=8, eval_every=2,
                              local_epochs=1),
        faults=api.FaultSpec(**faults_kwargs))


# ---------------------------------------------------------------------------
# spec surface
# ---------------------------------------------------------------------------

def test_fault_spec_round_trip_and_validation():
    spec = _spec(churn_rate=0.3, blackouts=2, nan_rate=0.1,
                 update_clip=10.0, checkpoint_every=5, seed=3)
    back = api.ExperimentSpec.from_json(spec.to_json())
    assert back == spec and back.hash() == spec.hash()
    # windows arrive as lists from JSON but compare as tuples
    assert isinstance(back.faults.churn_window, tuple)
    for bad, msg in [({"churn_rate": 1.5}, "churn_rate"),
                     ({"nan_rate": -0.1}, "nan_rate"),
                     ({"blackouts": -1}, "blackouts"),
                     ({"churn_downtime": 0.0}, "churn_downtime"),
                     ({"blackout_window": (40.0, 10.0)}, "blackout_window"),
                     ({"update_clip": -1.0}, "update_clip"),
                     ({"checkpoint_every": -2}, "checkpoint_every")]:
        with pytest.raises(api.SpecError, match=msg):
            _spec(**bad).validate()


def test_zero_fault_spec_builds_faultless_engine_config():
    """The default faults section must not even *construct* a FaultPlane:
    cfg.faults stays None, so the engine loop and the environment's
    alive() are byte-for-byte the pre-fault-plane code paths."""
    run = api.build(_spec())
    assert run.cfg.faults is None
    assert run.env.churn_down is None
    # checkpoint_every alone activates the config (for snapshots) but
    # must not inject faults
    run2 = api.build(_spec(checkpoint_every=4))
    assert run2.cfg.faults is not None
    assert not run2.cfg.faults.injects_faults


# ---------------------------------------------------------------------------
# churn schedule + env liveness
# ---------------------------------------------------------------------------

def test_churn_schedule_off_and_shapes():
    assert faults.churn_schedule(8, 0.0, 2, 30.0, (50.0, 400.0), 0) is None
    assert faults.churn_schedule(8, 0.5, 0, 30.0, (50.0, 400.0), 0) is None
    starts, ends = faults.churn_schedule(64, 0.5, 3, 30.0, (50.0, 400.0), 1)
    assert starts.shape == ends.shape == (64, 3)
    churners = np.isfinite(starts).all(axis=1)
    assert 0 < churners.sum() < 64
    # non-churners never go down; churners' windows sit inside the spec'd
    # onset window with positive durations, onsets sorted per client
    assert np.isinf(starts[~churners]).all()
    s, e = starts[churners], ends[churners]
    assert (s >= 50.0).all() and (s <= 400.0).all()
    assert (e > s).all()
    assert (np.diff(s, axis=1) >= 0).all()
    # dedicated stream: same seed -> same schedule, bitwise
    s2, e2 = faults.churn_schedule(64, 0.5, 3, 30.0, (50.0, 400.0), 1)
    assert np.array_equal(s, s2[churners]) and np.array_equal(e, e2[churners])


def test_env_alive_applies_churn_windows():
    env = SimEnv(_spec(churn_rate=1.0, churn_events=1, churn_downtime=20.0,
                       churn_window=(10.0, 11.0)).to_sim_config())
    starts, ends = env.churn_down
    assert env.alive(0.0).all()             # windows start at >= 10
    t_mid = float(starts[0, 0]) + 1e-3
    assert not env.alive(t_mid)[0]          # inside its down window
    assert env.alive(float(ends[0, 0]) + 1e-3)[0]   # back up afterwards
    # churn layers *on top of* permanent dropout, never revives it
    down_forever = env.dropout_at <= float(ends.max()) + 1.0
    assert not (env.alive(float(ends.max()) + 1.0) & down_forever).any()


def test_churn_changes_trajectory_deterministically():
    base = api.build(_spec()).run().metrics
    churny = _spec(churn_rate=0.8, churn_events=2, churn_downtime=40.0,
                   churn_window=(1.0, 60.0))
    m1 = api.build(churny).run().metrics
    m2 = api.build(churny).run().metrics
    assert m1.times == m2.times and m1.acc == m2.acc  # reproducible
    assert m1.times != base.times or m1.acc != base.acc  # and distinct


# ---------------------------------------------------------------------------
# blackouts + elastic Eq. 3 renormalization
# ---------------------------------------------------------------------------

def test_blackout_run_is_deterministic_and_finite():
    spec = _spec(blackouts=1, blackout_window=(1.0, 30.0),
                 blackout_duration=15.0)
    run = api.build(spec)
    assert run.cfg.faults.blackouts == 1
    m1 = run.run().metrics
    m2 = api.build(spec).run().metrics
    assert m1.times == m2.times and m1.acc == m2.acc
    assert np.isfinite(m1.acc).all()
    # the strategy ends with every tier back up (blackout windows are
    # short); tier state was bootstrapped, not left dark
    assert run.strategy.tier_alive.all()


def test_blackout_schedule_is_pure_function_of_config():
    cfg = faults.FaultConfig(blackouts=3, blackout_window=(10.0, 100.0),
                             blackout_duration=20.0, seed=7)
    p1, p2 = faults.FaultPlane(cfg, 4), faults.FaultPlane(cfg, 4)
    assert p1.blackout_events == p2.blackout_events
    assert len(p1.blackout_events) == 3
    for t0, t1, m in p1.blackout_events:
        assert 10.0 <= t0 <= 100.0 and t1 == t0 + 20.0 and 0 <= m < 4


def test_masked_cross_weights_renormalize_over_survivors():
    counts = np.array([5, 3, 2, 7], np.int64)
    alive = np.array([True, False, True, True])
    w = elastic.masked_cross_weights(counts, alive)
    assert w[1] == 0.0
    assert np.isclose(w.sum(), 1.0)
    # survivors carry the paper's reversed-count weights *as if only they
    # existed* — bitwise against Eq. 3 over the compressed counts
    assert np.array_equal(
        w[alive], aggregation.cross_tier_weights_host(counts[alive]))
    # all-alive degenerates to the unmasked Eq. 3 weights exactly
    all_on = np.ones(4, bool)
    assert np.array_equal(elastic.masked_cross_weights(counts, all_on),
                          aggregation.cross_tier_weights_host(counts))
    assert elastic.masked_cross_weights(counts, np.zeros(4, bool)).sum() == 0


def test_bootstrap_tier_overwrites_one_slot():
    tier_models = {"w": jnp.arange(12.0).reshape(3, 4)}
    w_global = {"w": jnp.full((4,), -1.0)}
    out = elastic.bootstrap_tier(tier_models, w_global, 1)
    assert np.array_equal(np.asarray(out["w"][1]), np.full(4, -1.0))
    assert np.array_equal(np.asarray(out["w"][0]),
                          np.asarray(tier_models["w"][0]))
    assert np.array_equal(np.asarray(out["w"][2]),
                          np.asarray(tier_models["w"][2]))


def test_shrink_grow_roundtrip_keeps_survivors_bitwise():
    """Losing a tier and re-adding one keeps the surviving tiers' params
    untouched (satellite: elastic coverage) and the newcomer lands on the
    Eq. 3 global model with zero count."""
    state = {
        "params": {"w": jnp.arange(12.0).reshape(4, 3)},
        "opt": {"m": jnp.ones((4, 3))},
        "step": jnp.full((4,), 7, jnp.int32),
        "counts": jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32),
    }
    shrunk = elastic.shrink_pods(state, keep=[0, 2, 3])
    grown = elastic.grow_pods(shrunk, 1)
    assert np.array_equal(np.asarray(grown["params"]["w"][:3]),
                          np.asarray(state["params"]["w"])[[0, 2, 3]])
    assert float(grown["counts"][-1]) == 0.0
    assert grown["params"]["w"].shape == (4, 3)
    w_expect = aggregation.global_model(shrunk["params"],
                                        shrunk["counts"])["w"]
    np.testing.assert_allclose(np.asarray(grown["params"]["w"][-1]),
                               np.asarray(w_expect), rtol=1e-6)


# ---------------------------------------------------------------------------
# update validation gate
# ---------------------------------------------------------------------------

def _stacked(vals):
    return {"w": jnp.asarray(vals, jnp.float32)}


def test_gate_zero_weights_nan_clients_and_renormalizes():
    params = _stacked([[1.0, 1.0], [np.nan, 2.0], [3.0, 3.0]])
    w = jnp.asarray([0.5, 0.25, 0.25])
    ref = {"w": jnp.zeros(2)}
    clean, gw, any_ok = fl_steps.gate_updates(params, w, ref, 0.0)
    assert bool(any_ok)
    gw = np.asarray(gw)
    assert gw[1] == 0.0 and np.isclose(gw.sum(), 1.0)
    np.testing.assert_allclose(gw[[0, 2]], [2 / 3, 1 / 3])
    # poisoned payload sanitized to ref: no NaN survives into the average
    assert np.isfinite(np.asarray(clean["w"])).all()
    np.testing.assert_array_equal(np.asarray(clean["w"][1]), [0.0, 0.0])


def test_gate_all_nan_reports_no_survivors():
    params = _stacked([[np.nan, 1.0], [2.0, np.inf]])
    _, gw, any_ok = fl_steps.gate_updates(
        params, jnp.asarray([0.5, 0.5]), {"w": jnp.zeros(2)}, 0.0)
    assert not bool(any_ok)
    assert np.asarray(gw).sum() == 0.0


def test_gate_clips_update_norm():
    ref = {"w": jnp.zeros(3)}
    params = _stacked([[3.0, 4.0, 0.0], [0.1, 0.0, 0.0]])   # norms 5, 0.1
    clean, _, _ = fl_steps.gate_updates(
        params, jnp.asarray([0.5, 0.5]), ref, 1.0)
    norms = np.linalg.norm(np.asarray(clean["w"]), axis=1)
    np.testing.assert_allclose(norms, [1.0, 0.1], rtol=1e-5)
    # direction preserved
    np.testing.assert_allclose(np.asarray(clean["w"][0]),
                               [0.6, 0.8, 0.0], rtol=1e-5)


def test_poison_updates_masks_only_flagged_clients():
    params = {"w": jnp.ones((3, 2)), "n": jnp.arange(3, dtype=jnp.int32)}
    out = fl_steps.poison_updates(params, jnp.asarray([False, True, False]))
    w = np.asarray(out["w"])
    assert np.isnan(w[1]).all()
    assert np.isfinite(w[[0, 2]]).all()
    # integer leaves pass through untouched
    assert np.array_equal(np.asarray(out["n"]), [0, 1, 2])


def test_draw_poison_stream_is_replayable():
    cfg = faults.FaultConfig(nan_rate=0.5, seed=11)
    p1, p2 = faults.FaultPlane(cfg, 2), faults.FaultPlane(cfg, 2)
    draws1 = [p1.draw_poison(3, 4) for _ in range(20)]
    draws2 = [p2.draw_poison(3, 4) for _ in range(20)]
    assert all(np.array_equal(a, b) for a, b in zip(draws1, draws2))
    assert any(d.any() for d in draws1)       # some rounds poisoned
    assert not all(d.any() for d in draws1)   # but not all
    for d in draws1:
        assert d.shape == (4,) and d.sum() <= 1 and not d[3:].any()


def test_nan_clients_cannot_sink_the_global_model():
    """Every round poisons one client; the gate keeps the whole
    trajectory finite (the acceptance bar: one bad client degrades a
    round, never the run)."""
    spec = _spec(nan_rate=1.0)
    res = api.build(spec).run()
    assert np.isfinite(res.metrics.acc).all()
    # and on fedavg too (same gate, different strategy wiring)
    res2 = api.build(spec.with_overrides(
        {"strategy.name": "fedavg", "strategy.kwargs": {}})).run()
    assert np.isfinite(res2.metrics.acc).all()


def test_gated_runs_are_deterministic_with_fixed_shapes():
    """The gated round step keeps the executor's one-trace-per-config
    contract: a full faulty run retraces nothing."""
    spec = _spec(nan_rate=0.5, update_clip=25.0, blackouts=1,
                 blackout_window=(1.0, 20.0), blackout_duration=10.0,
                 churn_rate=0.5, churn_window=(1.0, 40.0),
                 churn_downtime=15.0)
    run = api.build(spec)
    m1 = run.run().metrics
    assert all(v == 1 for v in run.env.executor().trace_counts.values())
    m2 = api.build(spec).run().metrics
    assert m1.times == m2.times and m1.acc == m2.acc
    assert np.isfinite(m1.acc).all()


def test_fault_config_activity_flags():
    assert not faults.FaultConfig().active
    assert faults.FaultConfig(checkpoint_every=5).active
    assert not faults.FaultConfig(checkpoint_every=5).injects_faults
    for kw in ({"blackouts": 1}, {"nan_rate": 0.1}, {"update_clip": 1.0}):
        assert faults.FaultConfig(**kw).injects_faults
    # frozen: fault configs are hashable spec mirrors
    with pytest.raises(dataclasses.FrozenInstanceError):
        faults.FaultConfig().nan_rate = 0.5


def test_is_fault_event_discriminates_actor_tuples():
    assert faults.is_fault_event((faults.BLACKOUT, 1, 20.0))
    assert faults.is_fault_event((faults.RETURN, 0))
    assert not faults.is_fault_event((0, np.arange(3)))   # round event
    assert not faults.is_fault_event((3, 0))              # fedasync event
    assert not faults.is_fault_event(5)


# ---------------------------------------------------------------------------
# population x faults (the two planes compose)
# ---------------------------------------------------------------------------

def _pop_fault_spec(**faults_kwargs):
    """Streaming-population variant of the small scenario with churn."""
    return api.ExperimentSpec(
        data=api.DataSpec(n_clients=64, samples_per_client=24, image_hw=8),
        tiers=api.TierSpec(n_tiers=2, clients_per_round=4, n_unstable=0),
        engine=api.EngineSpec(total_updates=8, eval_every=4,
                              local_epochs=1),
        faults=api.FaultSpec(**faults_kwargs),
        population=api.PopulationSpec(plane="streaming",
                                      availability="bernoulli:0.8:20",
                                      completion="bernoulli:0.9:20",
                                      seed=3))


def test_population_churned_clients_never_sampled():
    """Fault-plane churn windows and the population availability process
    both fold into alive(): a client inside a churn down-window (or an
    unavailable slot) never enters a sampling pool."""
    env = SimEnv(_pop_fault_spec(
        churn_rate=1.0, churn_events=1, churn_downtime=20.0,
        churn_window=(10.0, 11.0)).to_sim_config())
    starts, ends = env.churn_down
    rng = np.random.default_rng(0)
    t_mid = float(starts[0, 0]) + 1e-3
    alive = env.alive(t_mid)
    assert not alive[0]                       # churned down
    avail = env.population.availability_mask(t_mid)
    assert not alive[~avail].any()            # availability folded in too
    for _ in range(50):
        pool = np.arange(env.sc.n_clients)[alive]
        ids = env.sample_clients(pool, 4, rng)
        assert alive[ids].all()
        assert 0 not in ids


def test_population_completion_renormalizes_without_retrace():
    """Population completion drops survivors out of Eq. 4 inside the same
    fused step: a full churny streaming run retraces nothing and stays
    deterministic."""
    spec = _pop_fault_spec(churn_rate=0.5, churn_events=2,
                           churn_downtime=15.0, churn_window=(1.0, 40.0))
    api.clear_env_cache()
    run = api.build(spec)
    m1 = run.run().metrics
    tc = run.env.executor().trace_counts
    assert tc and all(v == 1 for v in tc.values())
    assert all("stream" in k for k in tc)
    m2 = api.build(spec).run().metrics
    assert m1.times == m2.times and m1.acc == m2.acc
    assert np.isfinite(m1.acc).all()
    api.clear_env_cache()


def test_population_composes_with_gate_and_blackouts():
    """The full stack at once: streaming population x churn x poisoning x
    gate x blackout stays finite, deterministic, and one-trace."""
    spec = _pop_fault_spec(nan_rate=0.5, update_clip=25.0, blackouts=1,
                           blackout_window=(1.0, 20.0),
                           blackout_duration=10.0)
    api.clear_env_cache()
    run = api.build(spec)
    m1 = run.run().metrics
    assert all(v == 1 for v in run.env.executor().trace_counts.values())
    m2 = api.build(spec).run().metrics
    assert m1.times == m2.times and m1.acc == m2.acc
    assert np.isfinite(m1.acc).all()
    api.clear_env_cache()
