"""Bitwise crash-resume (DESIGN.md §Fault-plane): an interrupted run,
resumed from its newest engine snapshot, replays the *exact* metrics
trajectory of an uninterrupted run — same floats, same byte counters,
same event order — with zero extra jit traces.  Pinned in-process (a
raising eval callback) and out-of-process (SIGKILL mid-run, the chaos
test)."""
import hashlib
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro import api

TOTAL = 12


def _spec(**faults_kwargs):
    kw = dict(churn_rate=0.5, churn_window=(1.0, 60.0),
              churn_downtime=20.0, checkpoint_every=2, seed=4)
    kw.update(faults_kwargs)
    return api.ExperimentSpec(
        data=api.DataSpec(n_clients=8, samples_per_client=24, image_hw=8),
        tiers=api.TierSpec(n_tiers=2, clients_per_round=2, n_unstable=0),
        engine=api.EngineSpec(total_updates=TOTAL, eval_every=2,
                              local_epochs=1),
        faults=api.FaultSpec(**kw))


def _fields(m):
    return [m.times, m.rounds, m.acc, m.acc_var, m.bytes_up, m.bytes_down]


def _traj_hash(m):
    doc = {"times": m.times, "rounds": m.rounds, "acc": m.acc,
           "acc_var": m.acc_var, "bytes_up": m.bytes_up,
           "bytes_down": m.bytes_down}
    return hashlib.sha256(
        json.dumps(doc, sort_keys=True).encode()).hexdigest()


class Abort(Exception):
    pass


def test_interrupted_run_resumes_bitwise(tmp_path):
    spec = _spec()
    ref = api.build(spec).run().metrics

    ck = str(tmp_path / "ck")
    seen = []

    def bomb(point):
        seen.append(point)
        if len(seen) == 2:
            raise Abort

    with pytest.raises(Abort):
        api.build(spec).run(on_eval=bomb, checkpoint_dir=ck)
    steps = [p for p in os.listdir(os.path.join(ck, "engine"))
             if p.startswith("step_")]
    assert steps, "the interrupted run left no engine snapshot"

    run = api.build(spec)
    res = run.run(checkpoint_dir=ck, resume_engine=True)
    assert _fields(res.metrics) == _fields(ref)
    # the resumed trajectory is the *whole* run, not just the tail: the
    # snapshot carries the metrics recorded before the crash
    assert len(res.metrics.acc) == len(ref.acc) > 2
    # zero extra recompiles: restored device state hits the executor's
    # existing compile-cache entries (env is shared via the api cache)
    assert all(v == 1 for v in run.env.executor().trace_counts.values())


def test_resume_from_final_snapshot_is_a_noop_replay(tmp_path):
    """Resuming a run that actually finished restores the final snapshot
    and exits the loop immediately — same trajectory, no extra work."""
    spec = _spec(checkpoint_every=TOTAL)   # snapshot lands at the end
    ck = str(tmp_path / "ck")
    ref = api.build(spec).run(checkpoint_dir=ck).metrics
    res = api.build(spec).run(checkpoint_dir=ck, resume_engine=True)
    assert _fields(res.metrics) == _fields(ref)


def test_resume_guards(tmp_path):
    spec = _spec()
    with pytest.raises(api.SpecError, match="resume_engine"):
        api.build(spec).run(resume_engine=True)   # no checkpoint_dir
    with pytest.raises(api.SpecError, match="no spec.json"):
        api.build(spec).run(checkpoint_dir=str(tmp_path / "empty"),
                            resume_engine=True)
    # a different spec may not resume (or even checkpoint) into the dir
    ck = str(tmp_path / "ck")
    api.build(spec).run(checkpoint_dir=ck)
    other = _spec(seed=9)
    with pytest.raises(api.SpecError, match="holds snapshots written by"):
        api.build(other).run(checkpoint_dir=ck)
    # specs without engine checkpointing reject resume_engine outright
    plain = api.ExperimentSpec.from_dict(spec.to_dict()).with_overrides(
        {"faults.checkpoint_every": 0})
    with pytest.raises(api.SpecError, match="resume_engine"):
        api.build(plain).run(checkpoint_dir=str(tmp_path / "ck2"),
                             resume_engine=True)


def test_snapshot_covers_retiering_and_blackouts(tmp_path):
    """Resume under the *full* fault surface: drifting tier maps and a
    blackout both ride the snapshot (the tier map and fault-stream
    position are part of engine state)."""
    spec = _spec(blackouts=1, blackout_window=(1.0, 30.0),
                 blackout_duration=15.0, nan_rate=0.3).with_overrides(
        {"tiers.retier_every": 3})
    ref = api.build(spec).run().metrics

    ck = str(tmp_path / "ck")
    seen = []

    def bomb(point):
        seen.append(point)
        if len(seen) == 2:
            raise Abort

    with pytest.raises(Abort):
        api.build(spec).run(on_eval=bomb, checkpoint_dir=ck)
    res = api.build(spec).run(checkpoint_dir=ck, resume_engine=True)
    assert _fields(res.metrics) == _fields(ref)
    assert np.isfinite(res.metrics.acc).all()


@pytest.mark.parametrize("strategy", ["fedavg", "fedasync"])
def test_resume_covers_every_strategy(tmp_path, strategy):
    spec = _spec().with_overrides({"strategy.name": strategy,
                                   "strategy.kwargs": {}})
    ref = api.build(spec).run().metrics
    ck = str(tmp_path / "ck")
    seen = []

    def bomb(point):
        seen.append(point)
        if len(seen) == 2:
            raise Abort

    with pytest.raises(Abort):
        api.build(spec).run(on_eval=bomb, checkpoint_dir=ck)
    res = api.build(spec).run(checkpoint_dir=ck, resume_engine=True)
    assert _fields(res.metrics) == _fields(ref)


# ---------------------------------------------------------------------------
# the chaos test: SIGKILL a real process mid-run, resume, compare hashes
# ---------------------------------------------------------------------------

def _cli_args(spec_path, ck, out):
    return [sys.executable, "-m", "repro.api.cli",
            "--spec", spec_path, "--checkpoint-dir", ck, "--out", out]


def test_sigkill_mid_run_resumes_to_identical_trajectory(tmp_path):
    spec = _spec()
    ref_hash = _traj_hash(api.build(spec).run().metrics)

    spec_path = str(tmp_path / "exp.json")
    with open(spec_path, "w") as f:
        f.write(spec.to_json())
    ck, out = str(tmp_path / "ck"), str(tmp_path / "out.json")
    env = dict(os.environ,
               PYTHONPATH="src", JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=1")

    proc = subprocess.Popen(_cli_args(spec_path, ck, out), env=env,
                            cwd=os.path.dirname(os.path.dirname(
                                os.path.abspath(__file__))),
                            stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL)
    # wait for the first engine snapshot to land, then kill -9
    eng = os.path.join(ck, "engine")
    deadline = time.time() + 180
    while time.time() < deadline and proc.poll() is None:
        if os.path.isdir(eng) and any(p.startswith("step_")
                                      for p in os.listdir(eng)):
            break
        time.sleep(0.05)
    try:
        os.kill(proc.pid, signal.SIGKILL)
    except ProcessLookupError:
        pass  # finished before we could kill it: resume still must agree
    proc.wait()
    assert os.path.isdir(eng) and any(p.startswith("step_")
                                      for p in os.listdir(eng)), \
        "no engine snapshot appeared before the deadline"

    r = subprocess.run(_cli_args(spec_path, ck, out) + ["--resume"],
                       env=env, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    with open(out) as f:
        doc = json.load(f)
    traj = doc["runs"][0]["trajectory"]
    got = hashlib.sha256(
        json.dumps(traj, sort_keys=True).encode()).hexdigest()
    assert got == ref_hash, (
        "resumed trajectory diverged from the uninterrupted run")
