"""Dry-run machinery in a subprocess with forced host devices.

The real 512-device dry-run is exercised by ``python -m repro.launch.dryrun``
(EXPERIMENTS.md §Dry-run); here a reduced mesh proves the same code path —
lower + compile + memory/cost/collective extraction — inside the test suite
without forcing 512 devices on every other test.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, jax, jax.numpy as jnp
    from repro.configs import registry, TrainConfig
    from repro.core import steps
    from repro.models import lm
    from repro.runtime import sharding as shd
    from repro.runtime.hlo import collective_bytes, count_collectives

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = registry.get_smoke_config("{arch}")
    tcfg = TrainConfig(fedat_enabled=True, fedat_sync_every=2,
                       fedat_compress_bits=8)
    with mesh, shd.use_mesh(mesh):
        fns = steps.make_fedat_step(cfg, tcfg, mesh)
        batch = {{"tokens": jax.ShapeDtypeStruct((2, 4, 128), jnp.int32)}}
        state = jax.eval_shape(fns.init_state, jax.random.PRNGKey(0))
        comp = jax.jit(fns.train_step,
                       in_shardings=(fns.state_shardings,
                                     fns.batch_shardings),
                       out_shardings=(fns.state_shardings, None)
                       ).lower(state, batch).compile()
    txt = comp.as_text()
    ca = comp.cost_analysis()           # dict on new jax, list on 0.4.x
    ca = (ca[0] if ca else {{}}) if isinstance(ca, list) else ca
    out = {{
        "colls": count_collectives(txt),
        "coll_bytes": collective_bytes(txt),
        "temp": comp.memory_analysis().temp_size_in_bytes,
        "flops": ca.get("flops", 0),
    }}
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.parametrize("arch", ["qwen2-7b", "rwkv6-3b"])
def test_multipod_fedat_compiles_on_8_devices(arch):
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(arch=arch)],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    # the compressed cross-tier collective must exist on the pod axis
    assert out["colls"].get("all-gather", 0) + \
        out["colls"].get("all-reduce", 0) > 0
    assert out["coll_bytes"] > 0
    assert out["flops"] > 0


INT_WIRE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import re, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs import registry, TrainConfig
    from repro.core import steps
    from repro.models import lm
    from repro.runtime import sharding as shd

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = registry.get_smoke_config("qwen2-7b")
    tcfg = TrainConfig(fedat_enabled=True, fedat_sync_every=1,
                       fedat_compress_bits=8)
    with mesh, shd.use_mesh(mesh):
        fns = steps.make_fedat_step(cfg, tcfg, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((2, 4, 128), jnp.int32)}
        state = jax.eval_shape(fns.init_state, jax.random.PRNGKey(0))
        txt = jax.jit(fns.train_step,
                      in_shardings=(fns.state_shardings,
                                    fns.batch_shardings),
                      out_shardings=(fns.state_shardings, None)
                      ).lower(state, batch).compile().as_text()
    # the optimization barriers must keep the pod collective on int8
    print("INTWIRE", bool(re.search(r"s8\\[[0-9,]*\\][^=]*all-gather", txt)))
""")


def test_compressed_wire_stays_int8():
    """Regression guard for the §Perf cell C lesson: without barriers XLA
    silently gathers the dequantized f32 payload."""
    proc = subprocess.run(
        [sys.executable, "-c", INT_WIRE_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "INTWIRE True" in proc.stdout, proc.stdout[-500:]
