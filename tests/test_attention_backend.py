"""The attention_backend knob: kernel-layer attention vs. the reference
oracle, from the raw ops up through a federated tiny_lm run.

Covers (ISSUE: flash-attention routing PR)

  * raw parity: ``kops.attention`` blocked vs. Pallas-interpret,
  * model-layer parity: ``full_attention`` flash vs. reference — forward
    and gradients, fp32 and bf16, across chunked/windowed/prefix/bidir
    mask configs,
  * backend resolution (auto/flash/reference x tp) and validation,
  * the federated path: a 2-round tiny_lm run per backend stays close in
    accuracy, traces each fused step exactly once, and the spec field
    changes the provenance hash.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro import kernels as K
from repro.configs.base import ATTENTION_BACKENDS
from repro.configs.tiny_lm import config as tiny_lm_config
from repro.models import attention as A
from repro.models import registry as model_registry


# ---------------------------------------------------------------------------
# raw kernel-layer parity
# ---------------------------------------------------------------------------

def _qkv(key, B=2, S=48, H=4, KV=2, hd=16, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), dtype)
    k = jax.random.normal(kk, (B, S, KV, hd), dtype)
    v = jax.random.normal(kv, (B, S, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("window", [None, 16])
def test_blocked_matches_pallas_interpret(window):
    q, k, v = _qkv(jax.random.PRNGKey(0))
    ob = K.attention(q, k, v, window=window, impl="blocked", block=16)
    op = K.attention(q, k, v, window=window, impl="pallas_interpret")
    assert float(jnp.max(jnp.abs(ob - op))) < 1e-5


def test_attention_impl_validation():
    q, k, v = _qkv(jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="unknown attention impl"):
        K.attention(q, k, v, impl="cuda")
    with pytest.raises(NotImplementedError, match="prefix"):
        K.attention(q, k, v, impl="pallas_interpret", prefix_len=4)
    assert K.default_attention_impl() in ("pallas", "blocked")
    # auto is callable end to end on whatever backend the tests run on
    out = K.attention(q, k, v, impl="auto")
    assert out.shape == q.shape


# ---------------------------------------------------------------------------
# model-layer parity: full_attention flash vs. reference
# ---------------------------------------------------------------------------

def _attn_params(cfg, key, dtype=jnp.float32):
    p = {}
    for name, s in A.attn_specs(cfg, tp=1).items():
        key, k2 = jax.random.split(key)
        p[name] = (jax.random.normal(k2, s.shape, jnp.float32) * 0.05
                   ).astype(dtype)
    return p


CASES = {
    "single-chunk": dict(),
    "chunked": dict(attn_chunk=16),
    "windowed": dict(swa_window=16, attn_chunk=16),
    "bidir": dict(causal=False),
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_full_attention_parity_fp32(case):
    cfg = tiny_lm_config().replace(**CASES[case])
    p = _attn_params(cfg, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 48, cfg.d_model))
    pos = jnp.arange(48)

    def run(backend, xx):
        c = cfg.replace(attention_backend=backend)
        return A.full_attention(c, p, xx, pos, tp=1)

    ref = run("reference", x)
    fl = run("flash", x)
    assert float(jnp.max(jnp.abs(ref - fl))) < 1e-5
    gr = jax.grad(lambda xx: jnp.sum(run("reference", xx) ** 2))(x)
    gf = jax.grad(lambda xx: jnp.sum(run("flash", xx) ** 2))(x)
    assert float(jnp.max(jnp.abs(gr - gf))) < 1e-4


def test_full_attention_parity_bf16():
    cfg = tiny_lm_config().replace(attn_chunk=16)
    p = _attn_params(cfg, jax.random.PRNGKey(4), dtype=jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 48, cfg.d_model),
                          jnp.bfloat16)
    pos = jnp.arange(48)

    def run(backend, xx):
        c = cfg.replace(attention_backend=backend)
        return A.full_attention(c, p, xx, pos, tp=1).astype(jnp.float32)

    ref = run("reference", x)
    fl = run("flash", x)
    # both paths accumulate softmax in fp32; bf16 rounding of inputs and
    # intermediates bounds the divergence at a few ulps of the output scale
    assert float(jnp.max(jnp.abs(ref - fl))) < 3e-2
    gr = jax.grad(lambda xx: jnp.sum(run("reference", xx) ** 2))(x)
    gf = jax.grad(lambda xx: jnp.sum(run("flash", xx) ** 2))(x)
    assert float(jnp.max(jnp.abs((gr - gf).astype(jnp.float32)))) < 1e-1


def test_prefix_lm_parity():
    cfg = tiny_lm_config().replace(attn_chunk=16)
    p = _attn_params(cfg, jax.random.PRNGKey(6))
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 48, cfg.d_model))
    pos = jnp.arange(48)
    ref = A.full_attention(cfg.replace(attention_backend="reference"),
                           p, x, pos, tp=1, prefix_len=8)
    fl = A.full_attention(cfg.replace(attention_backend="flash"),
                          p, x, pos, tp=1, prefix_len=8)
    assert float(jnp.max(jnp.abs(ref - fl))) < 1e-5


# ---------------------------------------------------------------------------
# resolution + validation
# ---------------------------------------------------------------------------

def test_backend_resolution():
    cfg = tiny_lm_config()
    assert A.resolve_attention_backend(cfg, tp=1) == "flash"       # auto
    assert A.resolve_attention_backend(
        cfg.replace(attention_backend="reference"), tp=1) == "reference"
    assert A.resolve_attention_backend(
        cfg.replace(attention_backend="flash"), tp=1) == "flash"
    # the TP contract: flash falls back to the reference path (it owns the
    # padded-head / kv_seq sharding story)
    for be in ATTENTION_BACKENDS:
        assert A.resolve_attention_backend(
            cfg.replace(attention_backend=be), tp=2) == "reference"
    with pytest.raises(ValueError, match="unknown attention_backend"):
        A.resolve_attention_backend(
            cfg.replace(attention_backend="fused"), tp=1)


def test_spec_validates_attention_backend():
    with pytest.raises(api.SpecError, match="attention_backend"):
        api.ExperimentSpec().with_overrides(
            {"data.attention_backend": "cuda"}).validate()
    for be in ATTENTION_BACKENDS:
        spec = api.ExperimentSpec().with_overrides(
            {"data.attention_backend": be}).validate()
        assert spec.to_sim_config().attention_backend == be
    # the backend is part of provenance: changing it changes the hash
    a = api.ExperimentSpec()
    b = a.with_overrides({"data.attention_backend": "reference"})
    assert a.hash() != b.hash()


def test_dims_reach_the_bound_model():
    dims = model_registry.DataDims(vocab_size=32, seq_len=12)
    for name in ("tiny_lm", "tiny_lm_long"):
        m = model_registry.build_model(
            name, model_registry.DataDims(
                vocab_size=32, seq_len=12, attention_backend="reference"))
        assert m.name == name
        assert m.batch_shape == (12,)
    # non-attention models ignore the knob
    m = model_registry.build_model("cnn", dims)
    assert m.data_kind == "image"


# ---------------------------------------------------------------------------
# the federated path: 2-round tiny_lm per backend
# ---------------------------------------------------------------------------

def _lm_spec(backend):
    return api.ExperimentSpec().with_overrides({
        "data.model": "tiny_lm", "data.n_clients": 8,
        "data.samples_per_client": 12, "data.vocab_size": 32,
        "data.seq_len": 12, "data.attention_backend": backend,
        "tiers.n_tiers": 2, "tiers.clients_per_round": 3,
        "tiers.n_unstable": 0, "engine.local_epochs": 1,
        "engine.total_updates": 2, "engine.eval_every": 1,
    }).validate()


@pytest.fixture(scope="module")
def fed_runs():
    out = {}
    for be in ("flash", "reference"):
        run = api.build(_lm_spec(be))
        out[be] = (run, run.run())
    return out


def test_federated_run_per_backend(fed_runs):
    for be, (run, res) in fed_runs.items():
        assert np.isfinite(res.metrics.acc).all(), be
        # one trace per fused-step configuration, flash included
        assert all(v == 1 for v in run.env.executor().trace_counts.values())


def test_federated_backends_agree(fed_runs):
    (_, res_f), (_, res_r) = fed_runs["flash"], fed_runs["reference"]
    assert res_f.metrics.rounds == res_r.metrics.rounds
    # identical data/schedule; only the attention math differs, so the
    # 2-round trajectories must agree to numerical-noise level
    np.testing.assert_allclose(np.asarray(res_f.metrics.acc),
                               np.asarray(res_r.metrics.acc),
                               rtol=0, atol=5e-3)
