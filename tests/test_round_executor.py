"""Fused round executor: fixed-shape compile-cache behaviour and the
zero-weight padding contract (DESIGN.md §Perf).

The executor's trace counters increment every time a fused step's Python
body is traced, so they measure compiles directly: a fixed-shape step must
trace exactly once per (strategy, codec, prox) configuration no matter how
dropout shrinks the per-event client sample.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import transport
from repro.core import aggregation
from repro.core.baselines import BaselineConfig, run_fedasync, run_fedavg, \
    run_tifl
from repro.core.fedat import FedATConfig, fake_polyline, run_fedat
from repro.core.simulation import SimConfig, SimEnv


@pytest.fixture(scope="module")
def env():
    return SimEnv(SimConfig(n_clients=12, n_tiers=3, samples_per_client=20,
                            classes_per_client=2, image_hw=8,
                            clients_per_round=4, local_epochs=1,
                            n_unstable=2))


def _bitwise_equal(a, b):
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# compile-cache regression: one trace per configuration, across shapes
# ---------------------------------------------------------------------------

def test_fedat_step_compiles_once_across_sample_sizes(env):
    """Full and dropout-shrunken samples reuse one compiled step."""
    ex = env.executor()
    codec = transport.get_codec("polyline:4")
    M = env.tm.n_tiers
    key = ("fedat", codec.name, True)
    before = ex.trace_counts.get(key, 0)
    w = jax.tree.map(jnp.array, env.params0)
    tms = jax.tree.map(lambda l: jnp.stack([l] * M), env.params0)
    cw = aggregation.uniform_weights(M)
    for ids in (np.arange(4), np.arange(3), np.arange(2), np.asarray([7])):
        w, tms = ex.fedat_round(w, tms, 0, ids.astype(np.int32), 1,
                                codec=codec, use_prox=True, cross_weights=cw)
    assert ex.trace_counts[key] - before == 1


def test_engine_run_with_dropouts_never_retraces(env):
    """A full engine run whose events include dropout-shrunken samples
    compiles each fused step exactly once (zero shape-driven retraces)."""
    ex = env.executor()
    before = dict(ex.trace_counts)
    # long enough to pass the earliest dropout times (uniform(50, 400))
    run_fedat(env, FedATConfig(total_updates=40, eval_every=20))
    run_fedavg(env, BaselineConfig(total_updates=12, eval_every=6))
    run_tifl(env, BaselineConfig(total_updates=12, eval_every=6))
    run_fedasync(env, BaselineConfig(total_updates=20, eval_every=10))
    for key, count in ex.trace_counts.items():
        assert count - before.get(key, 0) <= 1, (key, count)
    # repeated runs over the same env reuse the compile cache entirely
    snapshot = dict(ex.trace_counts)
    run_fedat(env, FedATConfig(total_updates=6, eval_every=6))
    run_fedavg(env, BaselineConfig(total_updates=4, eval_every=4))
    assert ex.trace_counts == snapshot


def test_distinct_codecs_compile_distinct_steps(env):
    ex = env.executor()
    run_fedat(env, FedATConfig(total_updates=2, eval_every=2,
                               codec="quantize8"))
    run_fedat(env, FedATConfig(total_updates=2, eval_every=2, codec="none"))
    assert ex.trace_counts[("fedat", "quantize8", True)] == 1
    assert ex.trace_counts[("fedat", "none", True)] == 1


# ---------------------------------------------------------------------------
# fixed-shape padding contract
# ---------------------------------------------------------------------------

def test_padded_round_matches_eager_reference_bitwise(env):
    """A dropout-shrunken sample padded to clients_per_round with
    zero-weight slots reproduces the eager variable-shape pipeline
    bit-for-bit (the engine-parity contract, checked here directly)."""
    ex = env.executor()
    codec = transport.get_codec("polyline:4")
    M = env.tm.n_tiers
    m, seed = 1, 20260801
    ids = np.asarray([5, 9], np.int32)           # shrunken: 2 of 4 slots
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ids))

    w_sent = fake_polyline(env.params0, 4)
    cp, _ = env.update_fn(w_sent, env.client_batch(ids), keys)
    cp = fake_polyline(cp, 4)
    tier_model = aggregation.intra_tier_average(cp, env.n_samples(ids))
    tms0 = jax.tree.map(lambda l: jnp.stack([l] * M), env.params0)
    stack_ref = jax.tree.map(lambda s, nw: s.at[m].set(nw), tms0, tier_model)
    cw = aggregation.cross_tier_weights(jnp.asarray([2, 1, 1]))
    wg_ref = aggregation.weighted_average(stack_ref, cw)

    wg, stack = ex.fedat_round(
        jax.tree.map(jnp.array, env.params0),
        jax.tree.map(lambda l: jnp.stack([l] * M), env.params0),
        m, ids, seed, codec=codec, use_prox=True, cross_weights=cw)
    assert _bitwise_equal(stack_ref, stack)
    assert _bitwise_equal(wg_ref, wg)


def test_zero_weight_slots_are_bitwise_neutral():
    """Adding zero-count slots to Eq. 4 changes nothing, bit for bit."""
    rng = np.random.default_rng(0)
    models = {"w": jnp.asarray(rng.normal(0, 0.1, (3, 64)).astype(np.float32))}
    padded = {"w": jnp.concatenate(
        [models["w"], models["w"][:1], models["w"][:1]], axis=0)}
    ns = jnp.asarray([17.0, 40.0, 23.0])
    ns_pad = jnp.asarray([17.0, 40.0, 23.0, 0.0, 0.0])
    a = aggregation.intra_tier_average(models, ns)
    b = aggregation.intra_tier_average(padded, ns_pad)
    assert _bitwise_equal(a, b)


def test_host_weight_twins_are_bitwise_identical():
    """The numpy hot-path weight helpers must match the jnp originals
    bit for bit (exact-integer inputs, correctly-rounded division)."""
    for counts in ([0, 0, 0], [1, 0, 2], [7, 13, 1], [123, 456, 789, 1]):
        a = np.asarray(aggregation.cross_tier_weights(jnp.asarray(counts)))
        b = aggregation.cross_tier_weights_host(np.asarray(counts))
        np.testing.assert_array_equal(a, b)
    for ns in ([40, 40, 40, 0], [17, 0, 0, 0], [0, 0], [3, 5, 60]):
        a = np.asarray(aggregation.client_weights(jnp.asarray(ns)))
        b = aggregation.client_weights_host(np.asarray(ns))
        np.testing.assert_array_equal(a, b)
    for n in (2, 3, 5, 7):
        np.testing.assert_array_equal(
            np.asarray(aggregation.uniform_weights(n)),
            aggregation.uniform_weights_host(n))


def test_alive_vectorized_matches_dropout_schedule(env):
    for now in (0.0, 49.9, 120.0, 1e9, *env.dropout_time.values()):
        expected = np.ones(env.sc.n_clients, bool)
        for c, t in env.dropout_time.items():
            if now >= t:
                expected[c] = False
        np.testing.assert_array_equal(env.alive(now), expected)
