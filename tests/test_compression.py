"""Compression codecs: faithful polyline + TPU blockwise quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis import given, settings, st  # property tests skip without hypothesis

from repro.compress import polyline, quantize


class TestPolyline:
    def test_known_google_example(self):
        # the reference values from Google's polyline documentation
        # (lat and lng are separate delta streams there)
        assert polyline.encode_values(np.array([38.5]), 5) == "_p~iF"
        assert polyline.encode_values(np.array([-120.2]), 5) == "~ps|U"

    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        x = rng.normal(0, 2, 500).astype(np.float32)
        for p in (3, 4, 6):
            dec = polyline.decode_values(polyline.encode_values(x, p), p)
            assert np.max(np.abs(dec - x)) <= 0.5 * 10 ** -p + 1e-9

    @given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                    min_size=1, max_size=50),
           st.integers(3, 6))
    @settings(max_examples=50, deadline=None)
    def test_property_roundtrip(self, vals, p):
        x = np.asarray(vals, np.float32)
        dec = polyline.decode_values(polyline.encode_values(x, p), p)
        assert len(dec) == len(x)
        # codec bound + f32 representation eps of the decoded magnitude
        tol = 0.5 * 10 ** -p + np.abs(x).max() * 2.4e-7 + 1e-6
        assert np.max(np.abs(dec - x)) <= tol

    def test_marshal_unmarshal_tree(self):
        tree = {"a": np.ones((3, 4), np.float32) * 0.12345,
                "b": {"c": np.linspace(-1, 1, 7, dtype=np.float32)}}
        msg = polyline.marshal(tree, precision=4)
        rt = polyline.unmarshal(msg)
        for k1, k2 in zip(jax.tree.leaves(tree), jax.tree.leaves(rt)):
            assert k1.shape == k2.shape
            assert np.max(np.abs(k1 - k2)) <= 5e-5 + 1e-9

    def test_compression_ratio(self):
        # small-magnitude deltas (typical trained weights) compress well
        rng = np.random.default_rng(0)
        w = (rng.normal(0, 0.05, 4096)).astype(np.float32)
        msg = polyline.marshal({"w": w}, precision=4)
        ratio = polyline.payload_bytes(msg) / polyline.raw_bytes({"w": w})
        assert ratio < 0.8  # beats raw f32 wire


class TestQuantize:
    @given(st.integers(1, 2000), st.sampled_from([8, 16]))
    @settings(max_examples=30, deadline=None)
    def test_property_error_bound(self, n, bits):
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.normal(0, 3, n), jnp.float32)
        c = quantize.compress(x, bits)
        xr = quantize.decompress(c, (n,))
        bound = np.asarray(quantize.error_bound(x, bits))
        err_blocks = np.abs(np.asarray(xr - x))
        pad = -(-n // quantize.BLOCK) * quantize.BLOCK
        errp = np.zeros(pad)
        errp[:n] = err_blocks
        per_block = errp.reshape(-1, quantize.BLOCK).max(1)
        assert np.all(per_block <= bound * (1 + 1e-4) + 1e-6)

    def test_wire_bytes_ratio(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=65536),
                        jnp.float32)
        c = quantize.compress(x, 8)
        assert quantize.wire_bytes(c) < 0.27 * x.size * 4  # ~3.9x vs f32

    def test_tree_roundtrip(self):
        tree = {"w": jnp.ones((130,)) * 0.5, "b": jnp.zeros((7,))}
        msg = quantize.compress_tree(tree, 8)
        rt = quantize.decompress_tree(msg)
        np.testing.assert_allclose(np.asarray(rt["w"]), 0.5, atol=1e-2)
        assert quantize.tree_wire_bytes(msg) > 0

    def test_fake_quantize_identity_shape(self):
        x = jnp.asarray(np.random.default_rng(1).normal(size=(5, 37)),
                        jnp.float32)
        y = quantize.fake_quantize(x, 8)
        assert y.shape == x.shape
        assert float(jnp.max(jnp.abs(y - x))) < 0.05
