"""Model registry (models/registry.py) + spec v3 migration.

Covers the redesign's contracts:

  * v1/v2 JSON documents (``data.task`` enum) parse under SPEC_VERSION 3
    through the deprecation shim, and shimmed specs run **bitwise
    identically** to the legacy SimEnv wrappers (the engine-parity oracle
    extended across the registry indirection).
  * Unknown model names fail with the registered-name list, everywhere a
    model can be named (spec validate, from_dict task shim, SimConfig).
  * ``tiny_lm`` — the LM facade on the federated path — runs end-to-end
    on a single device and on a 1-device host mesh with bitwise-equal
    trajectories and exactly one fused-step trace per configuration.
  * The token data plane is deterministic and partitioner-shaped.
"""
import json

import jax
import numpy as np
import pytest

from repro import api
from repro.core.fedat import FedATConfig, run_fedat
from repro.core.simulation import SimConfig, SimEnv
from repro.data.federated import make_federated
from repro.data.pipeline import class_token_sequences
from repro.models import registry as model_registry


def _small_overrides(**extra):
    d = {"data.n_clients": 12, "data.samples_per_client": 20,
         "data.image_hw": 8, "tiers.n_tiers": 3,
         "tiers.clients_per_round": 4, "tiers.n_unstable": 2,
         "engine.local_epochs": 1, "engine.total_updates": 6,
         "engine.eval_every": 3}
    d.update(extra)
    return d


def _lm_spec(**extra):
    return api.ExperimentSpec().with_overrides(_small_overrides(
        **{"data.model": "tiny_lm", "data.vocab_size": 32,
           "data.seq_len": 12, **extra}))


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------

def test_registry_entries_and_errors():
    assert model_registry.registered_models() == ["cnn", "logreg",
                                                  "tiny_lm", "tiny_lm_long"]
    dims = model_registry.DataDims()
    for name in model_registry.registered_models():
        m = model_registry.build_model(name, dims)
        assert m.name == name
        assert m.data_kind in ("image", "features", "tokens")
    with pytest.raises(ValueError, match=r"resnet.*registered.*cnn"):
        model_registry.build_model("resnet", dims)
    with pytest.raises(ValueError, match="already registered"):
        model_registry.register_model("cnn", model_registry.MODELS["cnn"])


def test_unknown_model_everywhere_lists_registered():
    with pytest.raises(api.SpecError, match=r"resnet.*registered.*"
                                            r"cnn.*logreg.*tiny_lm"):
        api.ExperimentSpec().with_overrides(
            {"data.model": "resnet"}).validate()
    with pytest.raises(ValueError, match=r"registered"):
        SimEnv(SimConfig(model="resnet", n_clients=4))


# ---------------------------------------------------------------------------
# v1/v2 migration: data.task -> data.model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("version", [1, 2])
@pytest.mark.parametrize("task,model", [("image", "cnn"),
                                        ("text", "logreg")])
def test_old_documents_parse_and_migrate(version, task, model):
    doc = {"spec_version": version,
           "data": {"task": task, "n_clients": 10},
           "engine": {"total_updates": 4}}
    spec = api.ExperimentSpec.from_json(json.dumps(doc))
    assert spec.data.model == model
    assert spec.to_dict()["spec_version"] == api.SPEC_VERSION == 7
    assert "task" not in spec.to_dict()["data"]
    spec.validate()


def test_task_shim_rejects_bad_values_and_conflicts():
    with pytest.raises(api.SpecError, match=r"task.*deprecated.*image"):
        api.ExperimentSpec.from_dict({"data": {"task": "audio"}})
    with pytest.raises(api.SpecError, match="conflicts"):
        api.ExperimentSpec.from_dict(
            {"data": {"task": "image", "model": "logreg"}})
    # the redundant spelling is allowed
    spec = api.ExperimentSpec.from_dict(
        {"data": {"task": "image", "model": "cnn"}})
    assert spec.data.model == "cnn"


def test_task_override_alias_still_sets_model():
    spec = api.ExperimentSpec().with_overrides({"data.task": "text"})
    assert spec.data.model == "logreg"
    # an explicit conflicting data.model override must error loudly
    # (never be silently replaced), regardless of key order
    with pytest.raises(api.SpecError, match="conflicts"):
        api.ExperimentSpec().with_overrides(
            {"data.model": "tiny_lm", "data.task": "image"})
    with pytest.raises(api.SpecError, match="conflicts"):
        api.ExperimentSpec().with_overrides(
            {"data.task": "image", "data.model": "tiny_lm"})
    # the redundant spelling stays allowed
    spec = api.ExperimentSpec().with_overrides(
        {"data.model": "cnn", "data.task": "image"})
    assert spec.data.model == "cnn"
    with pytest.raises(api.SpecError, match=r"task.*deprecated"):
        api.ExperimentSpec().with_overrides({"data.task": "audio"})


def _assert_bitwise(m_a, m_b):
    assert m_a.rounds == m_b.rounds
    assert m_a.times == m_b.times
    assert m_a.acc == m_b.acc
    assert m_a.acc_var == m_b.acc_var
    assert m_a.bytes_up == m_b.bytes_up
    assert m_a.bytes_down == m_b.bytes_down


@pytest.mark.parametrize("task", ["image", "text"])
def test_task_shim_runs_bitwise_identical_to_legacy_wrapper(task):
    """A shimmed v2 ``task`` spec reproduces the legacy SimEnv + run_fedat
    wrapper trajectory bit for bit through the registry path."""
    doc = {"spec_version": 2,
           "data": {"task": task, "n_clients": 12,
                    "samples_per_client": 20, "image_hw": 8,
                    "n_features": 32},
           "tiers": {"n_tiers": 3, "clients_per_round": 4,
                     "n_unstable": 2},
           "engine": {"local_epochs": 1, "total_updates": 6,
                      "eval_every": 3}}
    spec = api.ExperimentSpec.from_json(json.dumps(doc))
    env = SimEnv(spec.to_sim_config())          # seed-era construction
    m_legacy = run_fedat(env, FedATConfig(total_updates=6, eval_every=3))
    m_spec = api.run_spec(spec).metrics
    _assert_bitwise(m_spec, m_legacy)


# ---------------------------------------------------------------------------
# tiny_lm end-to-end (the LM facade on the federated path)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_result():
    run = api.build(_lm_spec())
    return run, run.run()


def test_tiny_lm_end_to_end(lm_result):
    run, res = lm_result
    env = run.env
    assert env.model.name == "tiny_lm"
    assert env.model.data_kind == "tokens"
    assert env.train["x"].dtype == np.int32
    # scan-stacked LM pytree flows through the whole stack
    assert "layers" in env.params0
    assert np.isfinite(res.metrics.acc).all()
    # every fused step traced exactly once (zero shape-driven retraces)
    assert all(v == 1 for v in env.executor().trace_counts.values())


def test_tiny_lm_host_mesh_1dev_bitwise_and_single_trace(lm_result):
    """A 1-device host mesh builds the byte-identical single-device steps
    for the LM exactly as for the paper models: same trajectory bitwise,
    same trace keys, one trace per configuration."""
    if len(jax.devices()) != 1:
        pytest.skip("needs exactly 1 device for the D==1 parity leg")
    run0, res0 = lm_result
    spec_mesh = _lm_spec(**{"mesh.kind": "host"})
    run1 = api.build(spec_mesh)
    res1 = run1.run()
    _assert_bitwise(res1.metrics, res0.metrics)
    ex0, ex1 = run0.env.executor(), run1.env.executor()
    assert set(ex1.trace_counts) == set(ex0.trace_counts)  # no "dataD" keys
    assert all(v == 1 for v in ex1.trace_counts.values())


def test_tiny_lm_sweeps_codecs_over_one_env():
    results = api.sweep(
        _lm_spec(**{"engine.total_updates": 2, "engine.eval_every": 2}),
        {"transport.codec": ["none", "quantize8"]})
    assert len(results) == 2
    assert results[1].metrics.bytes_up[-1] < results[0].metrics.bytes_up[-1]


# ---------------------------------------------------------------------------
# token data plane
# ---------------------------------------------------------------------------

def test_class_token_sequences_deterministic_and_class_conditional():
    labels = np.array([0, 0, 1, 1, 2])
    a = class_token_sequences(np.random.default_rng(0), labels, 32, 16)
    b = class_token_sequences(np.random.default_rng(0), labels, 32, 16)
    assert a.dtype == np.int32 and a.shape == (5, 16)
    np.testing.assert_array_equal(a, b)
    assert (a >= 0).all() and (a < 32).all()
    # distinct classes walk with distinct strides (mostly different seqs)
    assert not np.array_equal(a[0], a[2])


def test_make_federated_tokens_respects_partitioner():
    ds = make_federated(task="tokens", n_clients=8, n_classes=4,
                        classes_per_client=1, samples_per_client=24,
                        vocab_size=32, seq_len=12, seed=3)
    assert ds.input_shape == (12,)
    assert ds.input_dtype == np.int32
    for c in ds.clients:
        assert c.x_train.dtype == np.int32
        assert len(np.unique(c.y_train)) == 1   # 1 class per client
    with pytest.raises(ValueError, match="data kind"):
        make_federated(task="waveform")


def test_image_generation_unchanged_by_kind_refactor():
    """The image/features draw order is the pre-registry one: a fixed
    probe hash over a small image dataset pins it."""
    ds = make_federated(task="image", n_clients=3, n_classes=4,
                        classes_per_client=2, samples_per_client=20,
                        image_hw=4, seed=7)
    probe = float(np.sum([c.x_train.sum() for c in ds.clients]))
    assert ds.input_dtype == np.float32
    # legacy "text" alias still resolves to the features kind
    ds2 = make_federated(task="text", n_clients=2, n_features=16, seed=1)
    assert ds2.input_shape == (16,)
    assert np.isfinite(probe)
