"""Codec-agnostic transport layer (compress/transport.py): registry,
roundtrip bounds, wire accounting, the Pallas-kernel lossy step, and the
vectorized polyline encoder's equivalence with the scalar reference."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import polyline, quantize, transport


def _tree(seed=0, sizes=((33,), (4, 7), (256,), (130,))):
    rng = np.random.default_rng(seed)
    return {f"w{i}": rng.normal(0, 0.05, s).astype(np.float32)
            for i, s in enumerate(sizes)}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_get_codec_specs():
    assert transport.get_codec(None).name == "none"
    assert transport.get_codec("none").name == "none"
    assert transport.get_codec("polyline").precision == 4
    assert transport.get_codec("polyline:6").precision == 6
    assert transport.get_codec("quantize8").bits == 8
    assert transport.get_codec("quantize16").bits == 16
    assert transport.get_codec("quantize:16").bits == 16
    c = transport.get_codec("polyline:3")
    assert transport.get_codec(c) is c
    with pytest.raises(ValueError):
        transport.get_codec("gzip")


def test_cross_tier_bits():
    assert transport.cross_tier_bits("quantize8") == 8
    assert transport.cross_tier_bits("quantize16") == 16
    with pytest.raises(ValueError):
        transport.cross_tier_bits("polyline:4")


# ---------------------------------------------------------------------------
# polyline codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", [3, 4, 5])
def test_polyline_roundtrip_error_bound(precision):
    codec = transport.get_codec(f"polyline:{precision}")
    t = _tree()
    rt = codec.unmarshal(codec.marshal(t))
    for k in t:
        err = np.max(np.abs(t[k] - np.asarray(rt[k]).reshape(t[k].shape)))
        assert err <= 0.5 * 10.0 ** -precision + 1e-12


def test_polyline_payload_bytes_consistency():
    codec = transport.get_codec("polyline:4")
    t = _tree()
    msg = codec.marshal(t)
    assert codec.payload_bytes(msg) == (
        sum(len(p) for p in msg["payloads"]) + 8 * len(msg["shapes"]))
    # wire ratio below raw f32 for small-magnitude weights
    assert codec.measure_ratio(t) < 0.9
    assert transport.get_codec("none").measure_ratio(t) == 1.0


def test_polyline_lossy_matches_marshal_roundtrip():
    codec = transport.get_codec("polyline:4")
    t = _tree(sizes=((64,),))
    lossy = np.asarray(codec.lossy({"w": jnp.asarray(t["w0"])})["w"])
    rt = np.asarray(codec.unmarshal(codec.marshal({"w": t["w0"]}))["w"])
    np.testing.assert_allclose(lossy, rt, atol=1e-6)


def test_measure_ratio_sampling_close_to_full():
    x = {"w": np.random.default_rng(1).normal(0, 0.05, 200_000)
         .astype(np.float32)}
    codec = transport.get_codec("polyline:4")
    full = codec.measure_ratio(x, max_elems=None)
    sampled = codec.measure_ratio(x)  # default 65536-element cap
    assert abs(sampled - full) / full < 0.02


def test_measure_ratio_sampling_many_leaves():
    """Per-leaf fixed costs must not bias the sampled ratio on models with
    many leaves (the metadata is charged once, not scaled by the sample)."""
    rng = np.random.default_rng(3)
    t = {f"l{i}": rng.normal(0, 0.05, 500).astype(np.float32)
         for i in range(400)}  # 200k elems >> cap, 400 leaves
    codec = transport.get_codec("polyline:4")
    full = codec.measure_ratio(t, max_elems=None)
    sampled = codec.measure_ratio(t)
    assert abs(sampled - full) / full < 0.02


# ---------------------------------------------------------------------------
# vectorized encoder vs scalar reference
# ---------------------------------------------------------------------------

def test_vectorized_encoder_matches_reference():
    rng = np.random.default_rng(0)
    cases = [rng.normal(0, 0.05, 4096).astype(np.float32),
             rng.normal(0, 100, 1000),
             rng.uniform(-1e7, 1e7, 500),
             np.zeros(64),
             np.array([38.5]), np.array([-120.2]),
             np.array([])]
    for x in cases:
        for p in (3, 4, 5, 6):
            enc = polyline.encode_values(x, p)
            assert enc == polyline.encode_values_ref(x, p)
            np.testing.assert_array_equal(polyline.decode_values(enc, p),
                                          polyline.decode_values_ref(enc, p))


def test_vectorized_encoder_speedup():
    """Acceptance: >= 10x over the scalar reference on a 100k array.

    Measured in process CPU time (best of several runs) so noisy-neighbor
    scheduling on shared CI runners doesn't inflate the vectorized timing.
    """
    x = np.random.default_rng(0).normal(0, 0.05, 100_000).astype(np.float32)
    for _ in range(2):
        polyline.encode_values(x, 4)  # warm numpy caches
    # batch the fast path so each sample is well above the clock resolution
    t_vec = min(_cpu_timed(lambda: polyline.encode_values(x, 4), reps=5)
                for _ in range(3))
    t_ref = min(_cpu_timed(lambda: polyline.encode_values_ref(x, 4))
                for _ in range(2))
    assert t_ref / t_vec >= 10.0, f"only {t_ref / t_vec:.1f}x"


def _cpu_timed(fn, reps: int = 1):
    t0 = time.process_time()
    for _ in range(reps):
        fn()
    return (time.process_time() - t0) / reps


# ---------------------------------------------------------------------------
# quantize codec (Pallas kernel, interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 16])
def test_pallas_quantize_roundtrip_interpret(bits):
    codec = transport.QuantizeCodec(bits, interpret=True)
    x = {"w": jnp.asarray(np.random.default_rng(2)
                          .normal(0, 0.05, (37, 19)).astype(np.float32))}
    y = codec.lossy(x)
    assert y["w"].shape == x["w"].shape and y["w"].dtype == x["w"].dtype
    # kernel roundtrip obeys the blockwise error bound
    bound = np.asarray(quantize.error_bound(x["w"], bits)).max()
    err = np.max(np.abs(np.asarray(y["w"]) - np.asarray(x["w"])))
    assert err <= bound + 1e-8
    # and matches the jnp reference codec exactly
    ref = quantize.fake_quantize(x["w"], bits)
    np.testing.assert_allclose(np.asarray(y["w"]), np.asarray(ref),
                               atol=1e-7)


def test_quantize_wire_accounting():
    codec = transport.get_codec("quantize8")
    # leaves large enough to amortize the 256-block padding of the wire
    # format (tiny leaves are dominated by it)
    t = _tree(sizes=((1024,), (64, 32), (2000,)))
    msg = codec.marshal(t)
    assert codec.payload_bytes(msg) == quantize.tree_wire_bytes(msg)
    # the analytic ratio equals the marshalled payload ratio exactly
    raw = sum(v.nbytes for v in t.values())
    assert codec.measure_ratio(t) == pytest.approx(
        codec.payload_bytes(msg) / raw)
    # int8 wire: ~1 byte/element + scale overhead => well below f32
    assert codec.measure_ratio(t, max_elems=None) < 0.3
    rt = codec.unmarshal(msg)
    for k in t:
        bound = float(np.max(np.asarray(
            quantize.error_bound(jnp.asarray(t[k]), 8))))
        assert np.max(np.abs(t[k] - np.asarray(rt[k]))) <= bound + 1e-8


# ---------------------------------------------------------------------------
# FedAT end-to-end on the quantize codec (acceptance criterion)
# ---------------------------------------------------------------------------

def test_fedat_runs_with_quantize8_codec():
    from repro.core.fedat import FedATConfig, run_fedat
    from repro.core.simulation import SimConfig, SimEnv
    env = SimEnv(SimConfig(n_clients=8, n_tiers=2, samples_per_client=20,
                           classes_per_client=2, image_hw=8,
                           clients_per_round=3, local_epochs=1,
                           n_unstable=1))
    m = run_fedat(env, FedATConfig(total_updates=4, eval_every=2,
                                   codec="quantize8"))
    assert len(m.acc) >= 1 and np.isfinite(m.acc[-1])
    # bytes accounted at the int8 wire ratio, not raw f32
    raw = 3 * env.model_bytes * 4  # 4 rounds x <=3 clients, if uncompressed
    assert 0 < m.bytes_up[-1] < 0.35 * raw
