"""Serving-plane invariants (repro.serve): prefill/decode bitwise parity
with the training forward pass, continuous-batching conservation, slot
recycling, and spec-hash-addressed checkpoint loading."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api.build import save_checkpoint
from repro.api.spec import ExperimentSpec, SpecError
from repro.models import lm, transformer
from repro.models import registry as model_registry
from repro.serve import (LoadedCheckpoint, ServeEngine, ServeRequest,
                         ServeSpec, load_checkpoint, make_requests,
                         poisson_arrivals, report)


def _tiny(backend="reference"):
    """tiny_lm bound to the bitwise parity oracle (or another backend)."""
    model = model_registry.build_model(
        "tiny_lm", model_registry.DataDims(attention_backend=backend))
    cfg = model.config
    params = lm.init_params(cfg, jax.random.PRNGKey(0), 1, jnp.float32)
    return cfg, params


def _burst(cfg, n, max_new, prompt_len=16, seed=0):
    return make_requests(n, rate=0.0, prompt_len=prompt_len,
                         max_new=max_new, vocab_size=cfg.vocab_size,
                         seed=seed)


# ---------------------------------------------------------------------------
# (a) prefill + decode logits == full training forward, bitwise
# ---------------------------------------------------------------------------

def test_prefill_decode_logits_bitwise_match_full_forward():
    """The serve path (batched prefill then N greedy decode steps) must
    produce logits byte-identical to the training forward pass over the
    same final token sequence — the reference attention backend is the
    shape-stable oracle that makes this exact on XLA:CPU."""
    cfg, params = _tiny("reference")
    Lp, N = 12, 6
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, Lp).astype(np.int32)

    prefill = jax.jit(lambda p, t, lp, c: lm.serve_prefill(
        cfg, p, {"tokens": t}, 1, c, last_pos=lp))
    step = jax.jit(lambda p, t, po, c: lm.serve_step(cfg, p, t, po, 1, c))

    cache = lm.init_cache(cfg, 1, Lp + N, 1, jnp.float32)
    logits, cache = prefill(params, jnp.asarray(prompt[None]),
                            jnp.asarray([Lp - 1], jnp.int32), cache)
    served = [np.asarray(logits[0])]
    seq = list(prompt)
    for j in range(N - 1):
        nxt = int(np.argmax(served[-1][:cfg.vocab_size]))
        seq.append(nxt)
        logits, cache = step(params, jnp.asarray([nxt], jnp.int32),
                             jnp.asarray([Lp + j], jnp.int32), cache)
        served.append(np.asarray(logits[0]))
    seq.append(int(np.argmax(served[-1][:cfg.vocab_size])))

    @jax.jit
    def full(p, t):
        feats, _, _ = transformer.forward_train(cfg, p, {"tokens": t}, 1)
        return transformer.lm_head(cfg, p, feats)

    ref = np.asarray(full(params, jnp.asarray(np.asarray(seq)[None],
                                              jnp.int32))[0])
    for j, got in enumerate(served):
        want = ref[Lp - 1 + j]
        assert got.tobytes() == want.tobytes(), (
            f"decode step {j}: maxdiff "
            f"{np.abs(got - want).max()}")


# ---------------------------------------------------------------------------
# (b) conservation, slot recycling, trace discipline
# ---------------------------------------------------------------------------

def test_engine_conservation_and_one_trace_per_config():
    """7 requests through 3 slots: every request finishes with exactly
    max_new tokens, nothing is truncated, and each jitted function traced
    exactly once (fixed shapes — the one-trace-per-config contract)."""
    cfg, params = _tiny("auto")
    spec = ServeSpec(slots=3, max_len=48, prefill_len=16, max_new=6)
    engine = ServeEngine(cfg, params, spec)
    done = engine.run(_burst(cfg, 7, max_new=6))
    assert len(done) == 7
    assert sorted(r.rid for r in done) == list(range(7))
    assert all(len(r.out) == 6 and not r.truncated for r in done)
    assert all(r.t_admit <= r.t_first <= r.t_done for r in done)
    assert engine.trace_counts == {"prefill": 1, "decode": 1, "reset": 1}


def test_recycled_slot_bitwise_matches_fresh_slot():
    """A recycled slot (cache rows reset, per-slot position restarted at
    0, prompt force-fed through decode) must generate byte-for-byte what
    a fresh slot generates for the same prompt — and neighbours must not
    leak into it."""
    cfg, params = _tiny("reference")
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)
    other = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)

    spec = ServeSpec(slots=2, max_len=48, prefill_len=16, max_new=6)
    # rid 0+1 prefill as the first wave; rid 2 lands on a recycled slot
    reqs = [ServeRequest(0, prompt.copy(), 4),
            ServeRequest(1, other, 6),
            ServeRequest(2, prompt.copy(), 4)]
    done = {r.rid: r for r in ServeEngine(cfg, params, spec).run(reqs)}
    assert done[0].out == done[2].out

    # and both match the request served alone (no cross-slot leakage)
    alone = ServeEngine(cfg, params, spec).run(
        [ServeRequest(0, prompt.copy(), 4)])
    assert alone[0].out == done[0].out


def test_truncation_is_flagged():
    """max_len ends generation early -> truncated=True, distinguishable
    from a normally-finished request."""
    cfg, params = _tiny("auto")
    spec = ServeSpec(slots=1, max_len=12, prefill_len=8, max_new=64)
    rng = np.random.default_rng(2)
    req = ServeRequest(0, rng.integers(0, cfg.vocab_size, 8
                                       ).astype(np.int32), 64)
    done = ServeEngine(cfg, params, spec).run([req])
    assert done[0].truncated
    assert 0 < len(done[0].out) < 64


def test_open_loop_arrivals_are_deterministic():
    a = poisson_arrivals(16, rate=5.0, seed=3)
    b = poisson_arrivals(16, rate=5.0, seed=3)
    assert np.array_equal(a, b)
    assert np.all(np.diff(a) >= 0)
    assert np.array_equal(poisson_arrivals(8, rate=0.0, seed=3),
                          np.zeros(8))
    rep = report(ServeEngine(*_tiny("auto"), ServeSpec(
        slots=2, max_len=32, prefill_len=8, max_new=4)).run(
            make_requests(4, 50.0, 8, 4, 64, seed=0)))
    assert rep["requests"] == 4 and rep["tokens"] == 16
    assert rep["tok_per_s"] > 0
    assert rep["latency_p50_s"] <= rep["latency_p99_s"]


# ---------------------------------------------------------------------------
# (c) spec-hash-addressed checkpoints
# ---------------------------------------------------------------------------

def _lm_spec():
    return ExperimentSpec().with_overrides({
        "data.model": "tiny_lm", "data.n_clients": 8,
        "tiers.n_tiers": 2, "tiers.n_unstable": 0,
        "tiers.clients_per_round": 2, "engine.total_updates": 1,
    }).validate()


def test_checkpoint_roundtrip_bitwise(tmp_path):
    spec = _lm_spec()
    d = spec.data
    model = model_registry.build_model("tiny_lm", model_registry.DataDims(
        n_classes=d.n_classes, image_hw=d.image_hw,
        n_features=d.n_features, vocab_size=d.vocab_size,
        seq_len=d.seq_len, attention_backend=d.attention_backend))
    params = model.init_params(jax.random.PRNGKey(7))
    save_checkpoint(str(tmp_path), spec, params, step=3)

    loaded = load_checkpoint(str(tmp_path), expect_spec=spec)
    assert isinstance(loaded, LoadedCheckpoint)
    assert loaded.spec_hash == spec.hash()
    assert loaded.step == 3
    assert loaded.config is not None
    flat_a = jax.tree.leaves(params)
    flat_b = jax.tree.leaves(loaded.params)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_checkpoint_wrong_spec_hash_refused(tmp_path):
    spec = _lm_spec()
    model = model_registry.build_model("tiny_lm",
                                       model_registry.DataDims())
    save_checkpoint(str(tmp_path), spec,
                    model.init_params(jax.random.PRNGKey(0)), step=1)
    other = spec.with_overrides({"engine.lr": 0.123}).validate()
    with pytest.raises(SpecError, match="was written by spec"):
        load_checkpoint(str(tmp_path), expect_spec=other)
    # a hand-edited sidecar (hash no longer matches its own spec doc)
    side = os.path.join(str(tmp_path), "spec.json")
    with open(side) as f:
        doc = json.load(f)
    doc["spec_hash"] = "0" * 12
    with open(side, "w") as f:
        json.dump(doc, f)
    with pytest.raises(SpecError, match="self-inconsistent"):
        load_checkpoint(str(tmp_path))


def test_checkpoint_missing_or_nonservable(tmp_path):
    with pytest.raises(SpecError, match="no spec.json"):
        load_checkpoint(str(tmp_path / "nope"))
    # cnn has no decode path (FLModel.config is None)
    spec = ExperimentSpec().with_overrides({
        "data.model": "cnn", "data.n_clients": 8, "tiers.n_tiers": 2,
        "tiers.n_unstable": 0, "tiers.clients_per_round": 2,
    }).validate()
    model = model_registry.build_model("cnn", model_registry.DataDims())
    save_checkpoint(str(tmp_path), spec,
                    model.init_params(jax.random.PRNGKey(0)), step=1)
    with pytest.raises(SpecError, match="no decode path"):
        load_checkpoint(str(tmp_path))


def test_serve_spec_validation():
    with pytest.raises(SpecError):
        ServeSpec(slots=0).validate()
    with pytest.raises(SpecError):
        ServeSpec(prefill_len=99, max_len=64).validate()
    with pytest.raises(SpecError):
        ServeSpec(dtype="float16").validate()
    rt = ServeSpec.from_dict(ServeSpec(slots=7).to_dict())
    assert rt == ServeSpec(slots=7)
