"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


CODEC_SHAPES = [(8, 256), (16, 256), (64, 256)]


@pytest.mark.parametrize("nblocks", [8, 16, 64])
@pytest.mark.parametrize("bits", [8, 16])
def test_codec_kernel_matches_ref(nblocks, bits):
    x = _rand(nblocks, (nblocks, 256), jnp.float32) * 5
    from repro.kernels import polyline_codec as pc
    q, s = pc.compress_blocks(x, bits, interpret=True)
    qr, sr = ref.compress_blocks(x, bits)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    xr = pc.decompress_blocks(q, s, interpret=True)
    xref = ref.decompress_blocks(qr, sr)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(xref), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_codec_roundtrip_bound(dtype):
    x = (_rand(3, (2000,), jnp.float32) * 2).astype(dtype)
    q, s = ops.compress(x, 8)
    xr = ops.decompress(q, s, (2000,))
    tol = float(jnp.max(jnp.abs(x.astype(jnp.float32)))) / 127 * 0.51 + 0.01
    assert float(jnp.max(jnp.abs(xr - x.astype(jnp.float32)))) <= tol


ATTN_CASES = [
    # (S, T, H, KV, hd, causal, window)
    (128, 128, 4, 4, 64, True, None),
    (256, 256, 4, 2, 64, True, None),
    (200, 200, 4, 2, 80, True, None),       # unaligned S, hd
    (128, 128, 8, 1, 128, True, None),      # MQA
    (128, 384, 2, 2, 64, False, None),      # cross/bidirectional
    (256, 256, 4, 4, 64, True, 100),        # sliding window
    (512, 512, 2, 2, 64, True, 128),        # window == block
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    S, T, H, KV, hd, causal, window = case
    q = _rand(1, (2, S, H, hd), dtype)
    k = _rand(2, (2, T, KV, hd), dtype)
    v = _rand(3, (2, T, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    G = H // KV
    kr = jnp.repeat(k, G, 2).transpose(0, 2, 1, 3).reshape(2 * H, T, hd)
    vr = jnp.repeat(v, G, 2).transpose(0, 2, 1, 3).reshape(2 * H, T, hd)
    qr = q.transpose(0, 2, 1, 3).reshape(2 * H, S, hd)
    oref = ref.attention(qr, kr, vr, causal=causal, window=window)
    oref = oref.reshape(2, H, S, hd).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                oref.astype(jnp.float32))))
    assert err < tol, err


WKV_CASES = [(2, 64, 16, 32), (3, 100, 16, 32), (1, 256, 32, 64),
             (4, 33, 8, 16)]


@pytest.mark.parametrize("case", WKV_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_matches_ref(case, dtype):
    BH, S, N, chunk = case
    r = _rand(1, (BH, S, N), dtype)
    k = _rand(2, (BH, S, N), dtype)
    v = _rand(3, (BH, S, N), dtype)
    logw = (-jnp.exp(_rand(4, (BH, S, N), jnp.float32))).astype(jnp.float32)
    u = _rand(5, (BH, N), jnp.float32)
    y = ops.wkv6(r, k, v, logw, u, chunk=chunk)
    yr = ref.wkv6(r, k, v, logw, u)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32) -
                                yr.astype(jnp.float32))))
    assert err < tol, err


def test_wkv6_strong_decay_stable():
    # strong decays overflow a naive exp factorization; ours must not
    BH, S, N = 2, 128, 16
    r = _rand(1, (BH, S, N), jnp.float32)
    k = _rand(2, (BH, S, N), jnp.float32)
    v = _rand(3, (BH, S, N), jnp.float32)
    logw = jnp.full((BH, S, N), -8.0)
    u = jnp.zeros((BH, N))
    y = ops.wkv6(r, k, v, logw, u, chunk=64)
    assert bool(jnp.all(jnp.isfinite(y)))


SSD_CASES = [(2, 64, 16, 8, 32), (3, 100, 32, 16, 32), (1, 256, 64, 64, 64)]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_matches_ref(case, dtype):
    BH, S, P, N, chunk = case
    x = _rand(1, (BH, S, P), dtype)
    Bm = _rand(2, (BH, S, N), dtype)
    Cm = _rand(3, (BH, S, N), dtype)
    da = -jnp.abs(_rand(4, (BH, S, 1), jnp.float32))
    y = ops.ssd(x, Bm, Cm, da, chunk=chunk)
    yr = ref.ssd(x, Bm, Cm, da)
    tol = 5e-4 if dtype == jnp.float32 else 1e-1
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32) -
                                yr.astype(jnp.float32))))
    assert err < tol, err


def test_model_rwkv_block_matches_kernel():
    """models/rwkv6.py chunked-jnp path == the Pallas kernel semantics."""
    from repro.models.rwkv6 import _wkv_chunked, _wkv_step
    BH, S, H, N = 1, 64, 2, 16
    key = jax.random.PRNGKey(0)
    r = jax.random.normal(key, (BH, S, H, N))
    k = jax.random.normal(jax.random.PRNGKey(1), (BH, S, H, N))
    v = jax.random.normal(jax.random.PRNGKey(2), (BH, S, H, N))
    lw = -jnp.exp(jax.random.normal(jax.random.PRNGKey(3), (BH, S, H, N)))
    u = jax.random.normal(jax.random.PRNGKey(4), (H, N))
    state0 = jnp.zeros((BH, H, N, N))
    y_model, _ = _wkv_chunked(r, k, v, lw, u, state0)
    # kernel path: flatten (BH, H) -> BH*H
    rf = r.transpose(0, 2, 1, 3).reshape(BH * H, S, N)
    kf = k.transpose(0, 2, 1, 3).reshape(BH * H, S, N)
    vf = v.transpose(0, 2, 1, 3).reshape(BH * H, S, N)
    lwf = lw.transpose(0, 2, 1, 3).reshape(BH * H, S, N)
    uf = jnp.tile(u, (BH, 1))
    y_kern = ops.wkv6(rf, kf, vf, lwf, uf, chunk=32)
    y_kern = y_kern.reshape(BH, H, S, N).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kern),
                               atol=5e-4)
