"""Logical-axis sharding rules + HLO collective parser."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.runtime import sharding as shd
from repro.runtime.hlo import collective_bytes, count_collectives


def _mesh2():
    return make_mesh((len(jax.devices()), 1), ("data", "model"))


def test_rules_resolution():
    mesh = _mesh2()
    with shd.use_mesh(mesh):
        s = shd.logical_sharding(("batch", None, "tp"))
        # "pod" absent from this mesh: batch -> data only
        assert s.spec == P("data", None, "model")


def test_missing_axis_dropped():
    mesh = _mesh2()
    with shd.use_mesh(mesh, {"batch": ("pod", "data")}):
        s = shd.logical_sharding(("batch",))
        assert s.spec == P("data")


def test_rule_override():
    mesh = _mesh2()
    with shd.use_mesh(mesh, {"batch": None}):
        s = shd.logical_sharding(("batch", "tp"))
        assert s.spec == P(None, "model")


def test_duplicate_axis_suppressed():
    mesh = _mesh2()
    with shd.use_mesh(mesh, {"a": "data", "b": "data"}):
        s = shd.logical_sharding(("a", "b"))
        assert s.spec == P("data", None)  # an axis can be used once


def test_no_mesh_noop():
    with shd.use_mesh(None):
        x = jax.numpy.ones((4,))
        assert shd.shard(x, "batch") is x


def test_tp_size():
    mesh = _mesh2()
    with shd.use_mesh(mesh):
        assert shd.tp_size() == 1
    assert shd.tp_size() == 1  # no mesh -> 1


HLO_SAMPLE = """
ENTRY %main {
  %ag = bf16[2,512,128]{2,1,0} all-gather(%p0), replica_groups=[32,16]<=[512], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%p1), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(%p2), replica_groups=[4,4]<=[16], dimensions={0}
  %cp = s8[100]{0} collective-permute(%p3), source_target_pairs={{0,1}}
}
"""


def test_count_collectives():
    c = count_collectives(HLO_SAMPLE)
    assert c == {"all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
                 "collective-permute": 1}


def test_collective_bytes_estimate():
    b = collective_bytes(HLO_SAMPLE)
    ag = (16 - 1) / 16 * 2 * 512 * 128 * 2
    ar = 2 * 3 / 4 * 1024 * 4
    rs = 3 / 4 * 64 * 32 * 4
    cp = 100
    assert abs(b - (ag + ar + rs + cp)) / b < 0.01
