"""Checkpoint manager: roundtrip, retention, corruption fallback, async."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(v):
    return {"params": {"w": jnp.full((4, 4), float(v))},
            "step": jnp.asarray(v, jnp.int32)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(7, _state(7), blocking=True)
    restored, step = mgr.restore(_state(0))
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 7.0)


def test_keep_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _state(5), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_corruption_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _state(1), blocking=True)
    mgr.save(2, _state(2), blocking=True)
    # corrupt the newest shard
    shard = os.path.join(str(tmp_path), "step_0000000002", "shard_0.npz")
    with open(shard, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad\xbe\xef")
    restored, step = mgr.restore(_state(0))
    assert step == 1
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 1.0)


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        mgr.save(s, _state(s), blocking=True)
    restored, step = mgr.restore(_state(0), step=2)
    assert step == 2


def test_no_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state(0))


def test_atomic_tmp_cleanup(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _state(1), blocking=True)
    assert not any(n.endswith(".tmp") for n in os.listdir(str(tmp_path)))
