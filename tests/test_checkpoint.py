"""Checkpoint manager: roundtrip, retention, corruption fallback, async."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(v):
    return {"params": {"w": jnp.full((4, 4), float(v))},
            "step": jnp.asarray(v, jnp.int32)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(7, _state(7), blocking=True)
    restored, step = mgr.restore(_state(0))
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 7.0)


def test_keep_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _state(5), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_corruption_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _state(1), blocking=True)
    mgr.save(2, _state(2), blocking=True)
    # corrupt the newest shard
    shard = os.path.join(str(tmp_path), "step_0000000002", "shard_0.npz")
    with open(shard, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad\xbe\xef")
    restored, step = mgr.restore(_state(0))
    assert step == 1
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 1.0)


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        mgr.save(s, _state(s), blocking=True)
    restored, step = mgr.restore(_state(0), step=2)
    assert step == 2


def test_no_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state(0))


def test_atomic_tmp_cleanup(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _state(1), blocking=True)
    assert not any(n.endswith(".tmp") for n in os.listdir(str(tmp_path)))


def test_background_write_error_surfaces_on_next_save(tmp_path):
    """An async writer failure must not be silent until the final wait():
    the next save() joins the writer first and raises the stored error."""
    mgr = CheckpointManager(str(tmp_path), keep=3)

    def boom(step, host_state):
        raise IOError("disk full")

    orig, mgr._write = mgr._write, boom
    mgr.save(1, _state(1), blocking=False)
    with pytest.raises(IOError, match="disk full"):
        mgr.save(2, _state(2), blocking=False)
    # the error is consumed once; the manager stays usable afterwards
    mgr._write = orig
    mgr.save(3, _state(3), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 3


def test_background_write_error_surfaces_on_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)

    def boom(step, host_state):
        raise IOError("enospc")

    mgr._write = boom
    mgr.save(1, _state(1), blocking=False)
    with pytest.raises(IOError, match="enospc"):
        mgr.wait()


def test_gc_skips_in_flight_tmp(tmp_path):
    """keep-last-k GC must not delete a step another writer is mid-flight
    on (its ``.tmp`` sibling still exists)."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(1, _state(1), blocking=True)
    mgr.save(2, _state(2), blocking=True)
    assert mgr.all_steps() == [2]
    # simulate another writer that renamed step_3 but whose tmp re-write
    # is in flight (e.g. overwriting the same step)
    os.makedirs(str(tmp_path / "step_0000000003"))
    os.makedirs(str(tmp_path / "step_0000000003.tmp"))
    mgr.save(4, _state(4), blocking=True)
    assert 3 in mgr.all_steps()          # spared: tmp sibling present
    assert 2 not in mgr.all_steps()      # ordinary stale step collected
    assert 4 in mgr.all_steps()


def test_gc_never_collects_the_step_just_written(tmp_path):
    """A reused directory can hold higher-numbered steps from a previous
    run; GC prunes by ascending step but must spare the current write."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(10, _state(10), blocking=True)   # stale high step
    mgr.save(2, _state(2), blocking=True)     # current, numerically lower
    assert 2 in mgr.all_steps()


def test_save_fsyncs_payload_dir_and_parent(tmp_path, monkeypatch):
    """Durability order: shard file -> tmp dir -> rename -> parent dir.
    Without the trailing parent fsync the rename can vanish on power
    loss even though every file inside survived."""
    from repro.checkpoint import ckpt as ckpt_mod
    synced = []
    monkeypatch.setattr(ckpt_mod, "_fsync_path", synced.append)
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _state(1), blocking=True)
    assert len(synced) == 3
    assert synced[0].endswith("shard_0.npz")
    assert synced[1].endswith("step_0000000001.tmp")
    assert synced[2] == str(tmp_path)
