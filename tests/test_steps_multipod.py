"""Datacenter-scale FedAT step: multi-pod semantics on a host mesh.

Uses however many host devices exist; the conftest does NOT force a device
count, so these run with 1 device via a (1,1,1)-ish mesh — the sharded
512-device path is exercised by the dry-run (tests/test_dryrun_subprocess.py
runs a reduced version in a subprocess with 8 forced devices).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, registry
from repro.core import steps
from repro.launch import mesh as mesh_mod
from repro.runtime import sharding as shd


def _mesh(n_pods=2):
    n = len(jax.devices())
    if n % n_pods:
        n_pods = 1
    return mesh_mod.make_mesh(
        (n_pods, n // n_pods, 1), ("pod", "data", "model"))


@pytest.fixture(scope="module")
def setup():
    mesh = _mesh(1)  # single host device -> 1 pod slot, still pod-stacked
    cfg = registry.get_smoke_config("qwen2-7b")
    tcfg = TrainConfig(fedat_enabled=True, fedat_sync_every=2,
                       fedat_compress_bits=8, lr=1e-3)
    with mesh, shd.use_mesh(mesh):
        fns = steps.make_fedat_step(cfg, tcfg, mesh)
        state = jax.jit(fns.init_state)(jax.random.PRNGKey(0))
    return mesh, cfg, tcfg, fns, state


def _batch(cfg, n_pods, B=4, S=128, seed=0):
    toks = np.random.default_rng(seed).integers(
        0, cfg.vocab_size, (n_pods, B, S)).astype(np.int32)
    return {"tokens": jnp.asarray(toks)}


def test_counts_and_steps_advance(setup):
    mesh, cfg, tcfg, fns, state = setup
    n_pods = state["step"].shape[0]
    with mesh, shd.use_mesh(mesh):
        fn = jax.jit(fns.train_step)
        for i in range(3):
            state, m = fn(state, _batch(cfg, n_pods, seed=i))
    assert int(state["step"][0]) == 3
    np.testing.assert_allclose(np.asarray(state["counts"]), 3.0)
    assert np.isfinite(float(m["loss"]))


def test_pods_converge_at_sync(setup):
    mesh, cfg, tcfg, fns, state = setup
    n_pods = state["step"].shape[0]
    if n_pods < 2:
        pytest.skip("needs >= 2 pod slots")
    with mesh, shd.use_mesh(mesh):
        fn = jax.jit(fns.train_step)
        state, _ = fn(state, _batch(cfg, n_pods, seed=0))  # step 1: no sync
        leaf = np.asarray(jax.tree.leaves(state["params"])[1])
        assert not np.allclose(leaf[0], leaf[1])  # pods diverged
        state, _ = fn(state, _batch(cfg, n_pods, seed=1))  # step 2: sync
        leaf = np.asarray(jax.tree.leaves(state["params"])[1])
        np.testing.assert_allclose(leaf[0], leaf[1], atol=1e-6)


def test_loss_decreases_over_steps(setup):
    mesh, cfg, tcfg, fns, state = setup
    n_pods = state["step"].shape[0]
    b = _batch(cfg, n_pods, seed=42)
    losses = []
    with mesh, shd.use_mesh(mesh):
        fn = jax.jit(fns.train_step)
        for _ in range(8):
            state, m = fn(state, b)  # same batch: loss must fall
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_single_pod_step_runs():
    mesh = mesh_mod.make_mesh(
        (len(jax.devices()), 1), ("data", "model"))
    cfg = registry.get_smoke_config("granite-moe-3b-a800m")
    tcfg = TrainConfig(lr=1e-3)
    with mesh, shd.use_mesh(mesh):
        fns = steps.make_single_pod_step(cfg, tcfg, mesh)
        state = jax.jit(fns.init_state)(jax.random.PRNGKey(0))
        fn = jax.jit(fns.train_step)
        b = {"tokens": jnp.ones((4, 128), jnp.int32)}
        losses = []
        for _ in range(5):
            state, m = fn(state, b)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert int(state["step"]) == 5
