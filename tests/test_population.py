"""Population plane (core/population.py + the population spec section):

* the parity contract — the streaming/gather plane bitwise-equals the
  stacked plane at small N on the full engine-parity oracle surface
  (times, acc trajectory, wire bytes), with exactly one trace per step
  configuration and zero recompiles across rounds;
* the flat-memory invariant — a 100k-client streaming smoke run's peak
  data-plane bytes stay flat vs N=1k (device buffer shapes are a
  function of the config, not of N);
* the stochastic client-state processes (FLGo-style availability /
  responsiveness / completion) — determinism, spec-parameter
  convergence, and sampler interaction — both directly and as
  hypothesis property tests (tests/_hypothesis.py: the @given tests
  skip when hypothesis is not installed; the direct tests still run).
"""
import numpy as np
import pytest

from _hypothesis import given, settings, st
from repro import api
from repro.core import population as population_mod
from repro.core.population import Population, PopulationConfig
from repro.core.simulation import SimConfig, SimEnv


def _pop_spec(plane, n_clients=512, **over):
    spec = api.ExperimentSpec().with_overrides({
        "data.n_clients": n_clients, "data.samples_per_client": 20,
        "data.image_hw": 8, "tiers.n_tiers": 3,
        "tiers.clients_per_round": 8, "tiers.n_unstable": 16,
        "engine.local_epochs": 1, "engine.total_updates": 10,
        "engine.eval_every": 5,
        "population.plane": plane,
        "population.availability": "bernoulli:0.9:20",
        "population.completion": "bernoulli:0.95:20",
        "population.responsiveness": "lognormal:0.25",
        "population.eval_clients": 32, "population.seed": 3})
    return spec.with_overrides(over) if over else spec


def _pop(n=200, sc_over=None, **cfg_over):
    base = dict(plane="stacked", seed=3)
    base.update(cfg_over)
    sc_kw = dict(n_clients=n, samples_per_client=20, image_hw=8,
                 n_tiers=3, clients_per_round=8, n_unstable=8)
    sc_kw.update(sc_over or {})
    sc = SimConfig(population=PopulationConfig(**base), **sc_kw)
    from repro.models import registry as model_registry
    model = model_registry.build_model(sc.model, model_registry.DataDims(
        n_classes=sc.n_classes, image_hw=sc.image_hw,
        n_features=sc.n_features, vocab_size=sc.vocab_size,
        seq_len=sc.seq_len, attention_backend=sc.attention_backend))
    return Population(sc.population, sc, model)


# ---------------------------------------------------------------------------
# the parity contract: streaming bitwise-equals stacked at N <= 512
# ---------------------------------------------------------------------------

def test_streaming_bitwise_equals_stacked():
    """The tentpole oracle: at N=512 the streaming/gather plane must be
    bitwise-identical to the stacked plane on the whole metrics surface,
    with one trace per step configuration (zero recompiles across a
    10-update run) and a distinct ("stream",) trace-key tag."""
    api.clear_env_cache()
    res_stack = api.run_spec(_pop_spec("stacked"))
    env_stack = api.get_env(_pop_spec("stacked"))
    api.clear_env_cache()
    res_stream = api.run_spec(_pop_spec("streaming"))
    env_stream = api.get_env(_pop_spec("streaming"))

    ms, mr = res_stack.metrics, res_stream.metrics
    assert ms.times == mr.times
    assert ms.rounds == mr.rounds
    assert ms.acc == mr.acc
    assert ms.acc_var == mr.acc_var
    assert ms.bytes_up == mr.bytes_up
    assert ms.bytes_down == mr.bytes_down

    for tc in (env_stack._executor.trace_counts,
               env_stream._executor.trace_counts):
        assert tc and all(v == 1 for v in tc.values())
    assert all("stream" not in k for k in env_stack._executor.trace_counts)
    assert all("stream" in k for k in env_stream._executor.trace_counts)
    api.clear_env_cache()


@pytest.mark.parametrize("strategy", ["fedavg", "fedasync"])
def test_streaming_parity_other_strategies(strategy):
    """The shared _select step body keeps every strategy's streaming path
    bitwise, not just FedAT's."""
    over = {"strategy.name": strategy, "engine.total_updates": 6,
            "engine.eval_every": 3}
    api.clear_env_cache()
    m1 = api.run_spec(_pop_spec("stacked", n_clients=64, **over)).metrics
    api.clear_env_cache()
    m2 = api.run_spec(_pop_spec("streaming", n_clients=64, **over)).metrics
    assert m1.times == m2.times and m1.acc == m2.acc
    assert m1.bytes_up == m2.bytes_up
    api.clear_env_cache()


def test_population_runs_are_deterministic():
    spec = _pop_spec("streaming", n_clients=64)
    m1 = api.run_spec(spec).metrics
    m2 = api.run_spec(spec).metrics
    assert m1.times == m2.times and m1.acc == m2.acc
    api.clear_env_cache()


def test_default_population_section_is_legacy_plane():
    """All-defaults population == no population at all: same SimConfig
    (population=None), same environment, golden trajectories untouched."""
    spec = api.ExperimentSpec()
    assert spec.to_sim_config().population is None
    assert spec.population.to_config() is None
    # plane alone flips it on; seed alone does not
    on = spec.with_overrides({"population.plane": "stacked"})
    assert on.to_sim_config().population is not None
    seeded = spec.with_overrides({"population.seed": 9})
    assert seeded.to_sim_config().population is None


# ---------------------------------------------------------------------------
# the flat-memory invariant: 100k clients, flat peak device memory
# ---------------------------------------------------------------------------

def test_streaming_100k_smoke_flat_memory():
    """A 100k-client streaming run works and its peak data-plane bytes
    stay within 10% of the 1k-client run's (the acceptance bound): batch
    and eval buffer shapes depend on the config only, never on N."""
    def run(n):
        spec = _pop_spec("streaming", n_clients=n,
                         **{"tiers.n_unstable": n // 100,
                            "engine.total_updates": 2,
                            "engine.eval_every": 2})
        res = api.run_spec(spec)
        env = api.get_env(spec)
        bytes_peak = env.data_plane_bytes()
        api.clear_env_cache()
        return res.metrics, bytes_peak

    m1k, b1k = run(1_000)
    m100k, b100k = run(100_000)
    assert np.isfinite(m100k.acc).all() and len(m100k.acc) >= 1
    assert b100k <= 1.1 * b1k
    # ... while the population itself really is 100x bigger
    assert len(m100k.acc) == len(m1k.acc)


def test_batch_nbytes_is_n_independent():
    p_small, p_big = _pop(n=100), _pop(n=10_000)
    assert p_small.cap == p_big.cap
    assert p_small.batch_nbytes(8) == p_big.batch_nbytes(8)
    batch = p_big.materialize(np.arange(8))
    assert sum(a.nbytes for a in batch.values()) == p_big.batch_nbytes(8)


# ---------------------------------------------------------------------------
# indexed generator: lazy, order-independent, reproducible
# ---------------------------------------------------------------------------

def test_indexed_content_is_order_independent():
    """materialize(ids) must not depend on which clients were generated
    before — the property the legacy sequential generator lacks."""
    p = _pop(n=50)
    a = p.materialize(np.asarray([7, 3, 7, 40]))
    b = _pop(n=50).materialize(np.asarray([40, 7, 3, 7]))
    assert np.array_equal(a["x"][0], b["x"][1])   # client 7
    assert np.array_equal(a["x"][1], b["x"][2])   # client 3
    assert np.array_equal(a["x"][3], b["x"][0])   # client 40
    assert np.array_equal(a["x"][0], a["x"][2])   # duplicate id, one draw


def test_stack_matches_streamed_rows():
    """The stacked plane's resident stack is row-for-row the batches the
    streaming plane materializes (the data-level half of the parity)."""
    p = _pop(n=40)
    stack = p.materialize_stack()
    ids = np.asarray([0, 13, 39])
    batch = p.materialize(ids)
    for k in ("x", "y", "mask"):
        assert np.array_equal(stack[k][ids], batch[k])
    assert np.array_equal(stack["n_samples"], p.n_train)


def test_sizes_obey_static_cap_and_floor():
    p = _pop(n=5_000)
    assert p.cap == max(population_mod.CAP_FACTOR * 20,
                        population_mod.MIN_SAMPLES)
    assert (p.sizes >= population_mod.MIN_SAMPLES).all()
    assert (p.sizes <= p.cap).all()
    assert (p.n_train >= 1).all()
    assert p.cap_train + p.cap_test == p.cap


def test_class_pools_honor_partitioner():
    p = _pop(n=300)
    assert p.pools is not None and p.pools.shape == (300, 2)
    batch = p.materialize(np.arange(20))
    for c in range(20):
        got = set(np.unique(batch["y"][c][batch["mask"][c]]))
        assert got <= set(p.pools[c])
    pd = _pop(n=300, sc_over={"partitioner": "dirichlet:0.3"})
    assert pd.probs is not None and pd.probs.shape == (300, 10)
    assert np.allclose(pd.probs.sum(1), 1.0)


def test_tokens_kind_population():
    p = _pop(n=30, sc_over={"model": "tiny_lm"})
    batch = p.materialize(np.arange(4))
    assert batch["x"].dtype == np.int32
    assert batch["x"].shape[2:] == (16,)
    assert (batch["x"][batch["mask"]] >= 0).all()
    assert (batch["x"][batch["mask"]] < 64).all()


# ---------------------------------------------------------------------------
# stochastic client-state processes
# ---------------------------------------------------------------------------

def test_process_grammar_parses_and_rejects():
    assert population_mod.parse_process("always", "a", "always") is None
    assert population_mod.parse_process("bernoulli:0.9", "a", "always") \
        == (0.9, population_mod.DEFAULT_PERIOD)
    assert population_mod.parse_process("bernoulli:0.5:7", "a", "always") \
        == (0.5, 7.0)
    for bad in ("poisson:1", "bernoulli:2", "bernoulli:0.5:0",
                "bernoulli:x"):
        with pytest.raises(ValueError):
            population_mod.parse_process(bad, "a", "always")
    assert population_mod.parse_responsiveness("none") is None
    assert population_mod.parse_responsiveness("lognormal:0.5") \
        == ("lognormal", 0.5)
    assert population_mod.parse_responsiveness("uniform:0.5,2") \
        == ("uniform", (0.5, 2.0))
    for bad in ("gamma:1", "lognormal:x", "uniform:2,1", "uniform:0,1"):
        with pytest.raises(ValueError):
            population_mod.parse_responsiveness(bad)


def test_sine_grammar_parses_and_rejects():
    assert population_mod.parse_process("sine:0.7,0.25,240", "a", "always") \
        == ("sine", 0.7, 0.25, 240.0)
    for bad in ("sine:0.7", "sine:0.7,0.25", "sine:1.5,0.25,240",
                "sine:0.7,-0.1,240", "sine:0.7,0.25,0", "sine:x,y,z"):
        with pytest.raises(ValueError):
            population_mod.parse_process(bad, "a", "always")


def test_profile_grammar_parses_and_rejects():
    assert population_mod.parse_profile("none") is None
    assert population_mod.parse_profile("phone:0.3") == 0.3
    assert population_mod.parse_profile("phone:1") == 1.0
    for bad in ("tablet:0.5", "phone:0", "phone:1.5", "phone:x", "phone"):
        with pytest.raises(ValueError):
            population_mod.parse_profile(bad)


def test_sine_availability_is_diurnal():
    """The slot probability rides the sine wave: the high half-cycle of
    a period-240 wave has visibly more availability than the low half,
    and the mask stays a deterministic function of (seed, slot)."""
    p = _pop(n=20_000, availability="sine:0.5,0.4,240")
    # slot midpoints at t=60 (peak, p=0.9) and t=180 (trough, p=0.1)
    hi = p.availability_mask(60.0).mean()
    lo = p.availability_mask(180.0).mean()
    assert abs(hi - 0.9) < 0.02 and abs(lo - 0.1) < 0.02
    q = _pop(n=20_000, availability="sine:0.5,0.4,240")
    assert np.array_equal(p.availability_mask(60.0),
                          q.availability_mask(60.0))


def test_phone_profile_gates_only_the_phone_class():
    """profile='phone:0.5' applies the preset processes to a seeded half
    of the population; the other half stays always-on, always-complete,
    unit-latency."""
    p = _pop(n=20_000, profile="phone:0.5")
    phone = p._phone
    assert abs(phone.mean() - 0.5) < 0.02
    avail = p.availability_mask(10.0)
    compl = p.completion_mask(10.0)
    assert avail[~phone].all() and compl[~phone].all()
    assert not avail[phone].all()       # the sine process gates phones
    assert (p.resp_factors[~phone] == 1.0).all()
    assert not (p.resp_factors[phone] == 1.0).all()


def test_phone_profile_runs_end_to_end():
    res = api.build(api.ExperimentSpec().with_overrides({
        "data.n_clients": 64, "data.samples_per_client": 20,
        "data.image_hw": 8, "tiers.n_tiers": 2,
        "tiers.clients_per_round": 4, "tiers.n_unstable": 0,
        "engine.local_epochs": 1, "engine.total_updates": 6,
        "engine.eval_every": 3,
        "population.profile": "phone:0.3"})).run()
    assert res.metrics.times


def test_profile_owns_the_process_fields():
    with pytest.raises(api.SpecError, match="profile"):
        api.PopulationSpec(profile="phone:0.3",
                           responsiveness="lognormal:0.5").validate(100)
    with pytest.raises(api.SpecError, match="phone"):
        api.PopulationSpec(profile="watch:0.3").validate(100)


def test_availability_deterministic_and_slotted():
    p = _pop(n=400, availability="bernoulli:0.7:20")
    q = _pop(n=400, availability="bernoulli:0.7:20")
    m1, m2 = p.availability_mask(25.0), q.availability_mask(25.0)
    assert np.array_equal(m1, m2)                       # identical specs
    assert np.array_equal(m1, p.availability_mask(39.9))  # same slot
    assert not np.array_equal(m1, p.availability_mask(45.0))  # next slot
    assert _pop(n=400, availability="always").availability_mask(25.0) is None


def test_availability_rate_converges_to_spec():
    p = _pop(n=20_000, availability="bernoulli:0.8:20")
    rates = [p.availability_mask(t).mean() for t in (0.0, 30.0, 70.0)]
    assert all(abs(r - 0.8) < 0.02 for r in rates)


def test_completion_rate_converges_to_spec():
    p = _pop(n=20_000, completion="bernoulli:0.6:20")
    assert abs(p.completion_mask(10.0).mean() - 0.6) < 0.02
    assert _pop(n=100).completion_mask(10.0) is None


def test_responsiveness_factors_reshape_tiers():
    sc_kw = dict(n_clients=128, samples_per_client=20, image_hw=8,
                 n_tiers=3, clients_per_round=8, n_unstable=8)
    e0 = SimEnv(SimConfig(population=PopulationConfig(plane="stacked"),
                          **sc_kw))
    e1 = SimEnv(SimConfig(population=PopulationConfig(
        plane="stacked", responsiveness="lognormal:0.5"), **sc_kw))
    assert not np.array_equal(e0.tm.latencies, e1.tm.latencies)
    assert e1.population.resp_factors.shape == (128,)
    assert (e1.population.resp_factors > 0).all()
    # uniform grammar bounds the factors
    e2 = SimEnv(SimConfig(population=PopulationConfig(
        plane="stacked", responsiveness="uniform:0.5,2.0"), **sc_kw))
    f = e2.population.resp_factors
    assert (f >= 0.5).all() and (f <= 2.0).all()


def test_streams_are_independent():
    """Dedicated-stream contract: turning one knob never reshuffles
    another family's draws."""
    a = _pop(n=200, availability="bernoulli:0.9:20")
    b = _pop(n=200, availability="bernoulli:0.5:5",
             responsiveness="lognormal:0.5")
    assert np.array_equal(a.sizes, b.sizes)
    assert np.array_equal(a.pools, b.pools)
    ba, bb = a.materialize(np.arange(4)), b.materialize(np.arange(4))
    assert np.array_equal(ba["x"], bb["x"])
    # ... but a different population seed reshuffles everything
    c = _pop(n=200, seed=4)
    assert not np.array_equal(a.sizes, c.sizes)


def test_sampler_honors_availability_and_tier_membership():
    """sample_clients over alive() picks without replacement, only
    available clients, and only from the given tier's members."""
    sc_kw = dict(n_clients=256, samples_per_client=20, image_hw=8,
                 n_tiers=4, clients_per_round=8, n_unstable=16)
    env = SimEnv(SimConfig(population=PopulationConfig(
        plane="stacked", availability="bernoulli:0.6:20", seed=3), **sc_kw))
    rng = np.random.default_rng(0)
    for now in (0.0, 100.0, 333.0):
        alive = env.alive(now)
        avail = env.population.availability_mask(now)
        assert not alive[~avail].any()        # the mask is folded in
        for m in range(env.tm.n_tiers):
            members = env.tm.members[m]
            pool = members[alive[members]]
            ids = env.sample_clients(pool, 8, rng)
            assert len(ids) == len(set(ids.tolist()))  # no replacement
            assert alive[ids].all()
            assert np.isin(ids, members).all()


# ---------------------------------------------------------------------------
# property-based versions (skip cleanly without hypothesis)
# ---------------------------------------------------------------------------

@given(p=st.floats(0.1, 0.9), slot_seed=st.integers(0, 2**20),
       seed=st.integers(0, 2**20))
@settings(max_examples=20, deadline=None)
def test_prop_identical_specs_identical_draws(p, slot_seed, seed):
    cfg = dict(availability=f"bernoulli:{p}:20", seed=seed)
    now = float(slot_seed % 1000)
    m1 = _pop(n=300, **cfg).availability_mask(now)
    m2 = _pop(n=300, **cfg).availability_mask(now)
    assert np.array_equal(m1, m2)


@given(p=st.floats(0.2, 0.95), seed=st.integers(0, 2**20))
@settings(max_examples=10, deadline=None)
def test_prop_availability_rate_converges(p, seed):
    pop = _pop(n=20_000, availability=f"bernoulli:{p}:20", seed=seed)
    assert abs(pop.availability_mask(0.0).mean() - p) < 0.025


@given(now=st.floats(0, 500), k=st.integers(1, 16),
       rng_seed=st.integers(0, 2**20))
@settings(max_examples=20, deadline=None)
def test_prop_sampler_respects_masks(now, k, rng_seed, _env_cache={}):
    env = _env_cache.get("env")
    if env is None:
        env = _env_cache["env"] = SimEnv(SimConfig(
            population=PopulationConfig(
                plane="stacked", availability="bernoulli:0.6:20", seed=3),
            n_clients=256, samples_per_client=20, image_hw=8, n_tiers=4,
            clients_per_round=8, n_unstable=16))
    alive = env.alive(now)
    rng = np.random.default_rng(rng_seed)
    for m in range(env.tm.n_tiers):
        members = env.tm.members[m]
        pool = members[alive[members]]
        ids = env.sample_clients(pool, k, rng)
        assert len(ids) == min(k, len(pool))
        assert len(ids) == len(set(ids.tolist()))
        assert alive[ids].all() and np.isin(ids, members).all()
