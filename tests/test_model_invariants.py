"""Property tests on model-level invariants (hypothesis + direct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis import given, settings, st  # property tests skip without hypothesis

from repro.configs import registry
from repro.models import lm


def _params(cfg, seed=0):
    return lm.init_params(cfg, jax.random.PRNGKey(seed), tp=1)


def _logits_all(cfg, params, toks):
    """Full-sequence per-position logits via the loss-path features."""
    from repro.models import transformer, zamba2, rwkv6
    from repro.models.common import rms_norm
    if cfg.family in lm.TRANSFORMER_FAMILIES:
        x, _, _ = transformer.forward_train(cfg, params, {"tokens": toks}, 1)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return jnp.einsum("bsd,dv->bsv", x, head)
    if cfg.family == "hybrid":
        x = jnp.take(params["embed"], toks, axis=0)
        x, _ = zamba2._run(cfg, params, x, 1, "train")
        x = rms_norm(x, params["final_norm"], cfg.rms_eps)
        return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    state = rwkv6.init_state(cfg, toks.shape[0], 1, stacked=cfg.n_layers)
    x, _ = lm._rwkv_forward(cfg, params, toks, state, 1, False)
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


@pytest.mark.parametrize("arch", ["qwen2-7b", "rwkv6-3b", "zamba2-2.7b",
                                  "h2o-danube-3-4b"])
def test_causality(arch):
    """Perturbing a future token must not change past logits."""
    cfg = registry.get_smoke_config(arch)
    params = _params(cfg)
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (1, 48), 0, cfg.vocab_size)
    toks2 = toks.at[0, 40].set((toks[0, 40] + 1) % cfg.vocab_size)
    la = _logits_all(cfg, params, toks)
    lb = _logits_all(cfg, params, toks2)
    # positions strictly before the perturbation are bit-identical-ish
    assert float(jnp.max(jnp.abs(la[:, :40] - lb[:, :40]))) < 1e-5
    # and the perturbation is actually visible afterwards
    assert float(jnp.max(jnp.abs(la[:, 40:] - lb[:, 40:]))) > 1e-5


def test_encoder_is_not_causal():
    cfg = registry.get_smoke_config("hubert-xlarge")
    params = _params(cfg)
    from repro.models import transformer
    key = jax.random.PRNGKey(4)
    frames = jax.random.normal(key, (1, 32, cfg.d_model))
    batch = {"frames": frames}
    x, _, _ = transformer.forward_train(cfg, params, batch, 1)
    frames2 = frames.at[0, 30].add(1.0)
    x2, _, _ = transformer.forward_train(cfg, params, {"frames": frames2}, 1)
    # bidirectional: early positions DO see the late perturbation
    assert float(jnp.max(jnp.abs(x[:, :30] - x2[:, :30]))) > 1e-6


def test_swa_window_limits_receptive_field():
    cfg = registry.get_smoke_config("h2o-danube-3-4b")  # window 64
    # single layer so the receptive field == one window exactly
    cfg = cfg.replace(n_layers=1)
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 128), 0,
                              cfg.vocab_size)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    la = _logits_all(cfg, params, toks)
    lb = _logits_all(cfg, params, toks2)
    # position 100 is > window past token 0: unaffected in a 1-layer net
    assert float(jnp.max(jnp.abs(la[:, 100:] - lb[:, 100:]))) < 1e-5
    assert float(jnp.max(jnp.abs(la[:, 1:40] - lb[:, 1:40]))) > 1e-6


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=5, deadline=None)
def test_loss_deterministic(seed):
    cfg = registry.get_smoke_config("minitron-8b")
    params = _params(cfg, seed % 17)
    toks = jax.random.randint(jax.random.PRNGKey(seed), (2, 64), 0,
                              cfg.vocab_size)
    l1, _ = lm.loss_fn(cfg, params, {"tokens": toks}, 1)
    l2, _ = lm.loss_fn(cfg, params, {"tokens": toks}, 1)
    assert float(l1) == float(l2)


def test_batch_order_invariance():
    """Per-sequence logits are independent of batch companions."""
    cfg = registry.get_smoke_config("qwen2-7b")
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 48), 0,
                              cfg.vocab_size)
    la = _logits_all(cfg, params, toks)
    lb = _logits_all(cfg, params, toks[::-1])
    np.testing.assert_allclose(np.asarray(la[0]), np.asarray(lb[1]),
                               atol=1e-5)


def test_rwkv_state_carries_context():
    """Splitting a sequence across two prefills with carried state ==
    one prefill of the whole sequence (the recurrent contract)."""
    cfg = registry.get_smoke_config("rwkv6-3b")
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 64), 0,
                              cfg.vocab_size)
    s0 = lm.init_cache(cfg, 1, 64, 1, dtype=jnp.float32)
    full_logits, _ = lm.serve_prefill(cfg, params, {"tokens": toks}, 1, s0)
    s1 = lm.init_cache(cfg, 1, 64, 1, dtype=jnp.float32)
    _, s1 = lm.serve_prefill(cfg, params, {"tokens": toks[:, :32]}, 1, s1)
    part_logits, _ = lm.serve_prefill(cfg, params, {"tokens": toks[:, 32:]},
                                      1, s1)
    np.testing.assert_allclose(np.asarray(full_logits),
                               np.asarray(part_logits), atol=2e-3)
