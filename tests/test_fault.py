"""Fault-tolerance runtime: guarded steps, injected failures, stragglers,
elastic pod scaling."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime.fault import GuardedRunner, StragglerStats
from repro.runtime import elastic
from repro.runtime.straggler import FleetProfiler, sync_plan


def _step_fn(state, batch):
    return ({"x": state["x"] + batch["v"]},
            {"loss": jnp.sum(batch["v"])})


def _batches():
    while True:
        yield {"v": jnp.asarray(1.0)}


def test_guarded_runner_completes(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    r = GuardedRunner(_step_fn, ckpt, ckpt_every=5)
    state, end = r.run({"x": jnp.asarray(0.0)}, _batches(), 12)
    assert end == 12
    assert float(state["x"]) == 12.0
    assert ckpt.latest_step() == 12


def test_injected_failures_recovered(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    r = GuardedRunner(_step_fn, ckpt, ckpt_every=3,
                      inject_failure_rate=0.3, seed=1, max_retries=50)
    state, end = r.run({"x": jnp.asarray(0.0)}, _batches(), 15)
    assert end == 15
    assert r.stats["failures"] > 0  # failures actually happened
    assert float(state["x"]) >= 1.0  # training progressed


def test_failure_restores_from_checkpoint(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    calls = itertools.count()

    def flaky(state, batch):
        n = next(calls)
        if n == 7:
            raise RuntimeError("node died")
        return _step_fn(state, batch)

    r = GuardedRunner(flaky, ckpt, ckpt_every=2, max_retries=3)
    state, end = r.run({"x": jnp.asarray(0.0)}, _batches(), 10)
    assert end == 10
    assert r.stats["failures"] == 1
    assert r.stats["restores"] == 1


def test_backoff_is_clock_injectable_and_deterministic(tmp_path):
    """The retry/backoff path never touches the real clock: with an
    injected sleep/clock the whole injected-failure schedule — which
    attempts fail, how many retries each batch takes, and every backoff
    duration — is an exact replay of the runner's seeded rng stream."""
    rate, seed, n_steps = 0.35, 42, 30

    # reference simulation of the runner's draw discipline: one
    # rng.random() per attempt, retries reset per batch, no checkpoint
    # exists (ckpt_every > n_steps) so a failure retries in place
    rng = np.random.default_rng(seed)
    expected_sleeps, expected_failures = [], 0
    for _ in range(n_steps):
        retries = 0
        while rng.random() < rate:
            expected_failures += 1
            retries += 1
            expected_sleeps.append(min(0.05 * 2 ** retries, 1.0))

    sleeps, ticks = [], itertools.count()
    r = GuardedRunner(_step_fn, CheckpointManager(str(tmp_path)),
                      ckpt_every=10_000, max_retries=50,
                      inject_failure_rate=rate, seed=seed,
                      sleep=sleeps.append,
                      clock=lambda: next(ticks) * 0.01)
    state, end = r.run({"x": jnp.asarray(0.0)}, _batches(), n_steps)
    assert end == n_steps
    assert float(state["x"]) == float(n_steps)
    assert r.stats["failures"] == expected_failures > 0
    assert sleeps == expected_sleeps


def test_straggler_detection():
    st = StragglerStats(threshold=2.0)
    for _ in range(20):
        st.observe(0.1)
    assert st.observe(0.5) is True
    assert st.observe(0.1) is False


def test_fleet_profiler_tier_map():
    fp = FleetProfiler(8)
    for w in range(8):
        for _ in range(5):
            fp.observe(w, 0.1 * (w + 1))
    tm = fp.build_tier_map(4)
    plan = sync_plan(tm)
    assert len(plan["tiers"]) == 4
    assert plan["relative_rates"][0] == 1.0         # fastest tier
    assert plan["relative_rates"][-1] < 0.5          # slowest much slower


# ---- elastic -----------------------------------------------------------

def _pod_state(n_pods):
    return {
        "params": {"w": jnp.arange(float(n_pods))[:, None] *
                   jnp.ones((n_pods, 3))},
        "opt": {"m": jnp.zeros((n_pods, 3))},
        "step": jnp.full((n_pods,), 5, jnp.int32),
        "counts": jnp.asarray(np.arange(1, n_pods + 1), jnp.float32),
    }


def test_shrink_pods():
    s = elastic.shrink_pods(_pod_state(4), keep=[0, 2])
    assert s["params"]["w"].shape[0] == 2
    np.testing.assert_allclose(np.asarray(s["counts"]), [1.0, 3.0])


def test_grow_pods_bootstraps_from_global():
    s0 = _pod_state(2)
    s = elastic.grow_pods(s0, 1)
    assert s["params"]["w"].shape[0] == 3
    assert float(s["counts"][-1]) == 0.0  # newcomer has no updates yet
    # newcomer params = Eq.3 mix of survivors
    from repro.core import aggregation
    w_expect = aggregation.global_model(s0["params"], s0["counts"])["w"]
    np.testing.assert_allclose(np.asarray(s["params"]["w"][-1]),
                               np.asarray(w_expect), rtol=1e-6)
