"""FedAT aggregation invariants (Eq. 3 / Eq. 4 / Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis import given, settings, st  # property tests skip without hypothesis

from repro.core import aggregation as agg


class TestCrossTierWeights:
    def test_sums_to_one(self):
        w = agg.cross_tier_weights(jnp.array([5.0, 3.0, 1.0]))
        assert np.isclose(float(jnp.sum(w)), 1.0)

    def test_reversal(self):
        # tier m gets the count of tier M+1-m (Eq. 3)
        counts = jnp.array([6.0, 3.0, 1.0])
        w = agg.cross_tier_weights(counts)
        np.testing.assert_allclose(np.asarray(w), [0.1, 0.3, 0.6], atol=1e-6)

    def test_zero_counts_uniform(self):
        w = agg.cross_tier_weights(jnp.zeros(4))
        np.testing.assert_allclose(np.asarray(w), 0.25, atol=1e-6)

    def test_slowest_gets_largest_weight(self):
        # faster tiers have higher counts -> slower tiers get bigger weights
        counts = jnp.array([10.0, 7.0, 4.0, 2.0, 1.0])
        w = np.asarray(agg.cross_tier_weights(counts))
        assert np.all(np.diff(w) > 0)

    @given(st.lists(st.integers(0, 1000), min_size=2, max_size=8))
    @settings(max_examples=50, deadline=None)
    def test_property_simplex(self, counts):
        w = np.asarray(agg.cross_tier_weights(jnp.asarray(counts, jnp.float32)))
        assert np.all(w >= 0)
        assert np.isclose(w.sum(), 1.0, atol=1e-5)

    @given(st.lists(st.integers(1, 1000), min_size=2, max_size=8))
    @settings(max_examples=50, deadline=None)
    def test_property_reversal(self, counts):
        w = np.asarray(agg.cross_tier_weights(jnp.asarray(counts, jnp.float32)))
        expect = np.asarray(counts, np.float64)[::-1] / np.sum(counts)
        np.testing.assert_allclose(w, expect, atol=1e-5)


class TestWeightedAverage:
    def test_matches_manual(self):
        models = {"w": jnp.arange(12.0).reshape(3, 4)}
        weights = jnp.array([0.5, 0.25, 0.25])
        out = agg.weighted_average(models, weights)
        expect = 0.5 * models["w"][0] + 0.25 * models["w"][1] + \
            0.25 * models["w"][2]
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(expect))

    def test_intra_tier_sample_weighting(self):
        # Eq. 4: client k weighted by n_k / N_c
        models = {"w": jnp.stack([jnp.ones(3), 3 * jnp.ones(3)])}
        out = agg.intra_tier_average(models, jnp.array([10, 30]))
        np.testing.assert_allclose(np.asarray(out["w"]), 2.5)

    def test_identity_single_tier(self):
        models = {"w": jnp.ones((1, 5)) * 7}
        out = agg.global_model(models, jnp.array([3.0]))
        np.testing.assert_allclose(np.asarray(out["w"]), 7.0)

    def test_permutation_consistency(self):
        # aggregating permuted tiers with permuted counts gives same result
        rng = np.random.default_rng(0)
        leaves = rng.normal(size=(4, 6)).astype(np.float32)
        counts = np.array([8.0, 4.0, 2.0, 1.0], np.float32)
        out = agg.global_model({"w": jnp.asarray(leaves)}, jnp.asarray(counts))
        # reversal-aware permutation: reversing both tiers and counts
        out2 = agg.global_model({"w": jnp.asarray(leaves[::-1].copy())},
                                jnp.asarray(counts[::-1].copy()))
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(out2["w"]), atol=1e-6)
