"""Analytic FLOP/byte model (the MODEL_FLOPS side of the roofline ratio).

MODEL_FLOPS follows the assignment's definition:
    train   : 6 * N * D        (N = params; N_active for MoE)
    prefill : 2 * N * D
    decode  : 2 * N * B        (one token per sequence)
with D = tokens processed.  Attention score/PV FLOPs are *excluded* here by
definition — they show up in HLO_FLOPS, so the reported ratio
MODEL_FLOPS / HLO_FLOPS surfaces attention cost, head/vocab padding waste,
MoE dispatch overhead and remat recompute all at once (per-cell notes in
EXPERIMENTS.md attribute which is dominant).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig


def tokens(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if shape.kind == "decode":
        return shape.global_batch  # one new token per sequence
    return shape.global_batch * shape.seq_len


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n = cfg.active_param_count() if cfg.family == "moe" else cfg.param_count()
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens(cfg, shape)


def attention_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Extra QK^T + PV FLOPs (not in 6ND): reported as context, and used by
    the per-cell notes to attribute the MODEL/HLO gap."""
    if cfg.family == "ssm":
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    H, hd = cfg.n_heads, cfg.head_dim
    L = cfg.n_layers if cfg.family != "hybrid" else \
        cfg.n_layers // max(cfg.attn_every, 1)
    if shape.kind == "decode":
        kv_len = min(S, cfg.swa_window or S)
        per = 2 * 2 * B * H * hd * kv_len        # QK + PV vs full cache
        return float(L * per)
    kv_len = min(S, cfg.swa_window or S)
    causal = 0.5 if (cfg.causal and cfg.swa_window is None) else 1.0
    per = 2 * 2 * B * S * kv_len * H * hd * causal
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd
    return float(L * per * mult)


def hbm_bytes_estimate(cfg: ModelConfig, shape: ShapeConfig,
                       n_devices: int) -> float:
    """Analytic per-device HBM floor: weights (+opt for train) + KV cache per
    step.  Used to sanity-check memory_analysis (the CPU host backend
    promotes loop-carried bf16 buffers to f32, inflating temp <= 2x)."""
    n = cfg.param_count()
    per_dev = n / n_devices
    if shape.kind == "train":
        micro = max(cfg.microbatch, 1)
        return per_dev * (2 + 4 + 8) + \
            2 * cfg.n_layers * (shape.global_batch / micro) * \
            shape.seq_len * cfg.d_model / n_devices * 16
    cache = 0.0
    if cfg.family in ("dense", "moe", "vlm"):
        kv_len = min(shape.seq_len, cfg.swa_window or shape.seq_len)
        cache = (2 * cfg.n_layers * shape.global_batch * kv_len *
                 cfg.n_kv_heads * cfg.head_dim * 2) / n_devices
    return per_dev * 2 + cache
