"""Render EXPERIMENTS tables from the experiment JSON artifacts.

  PYTHONPATH=src:. python -m benchmarks.report > experiments/tables.md
"""
from __future__ import annotations

import json
import os

EXP = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "experiments")


def _load(name):
    path = os.path.join(EXP, name)
    return json.load(open(path)) if os.path.exists(path) else None


def dryrun_tables():
    for tag, chips in (("single", 256), ("multi", 512)):
        rs = _load(f"dryrun_{tag}.json")
        if not rs:
            continue
        rows = [r for r in rs if "peak_bytes_per_device" in r]
        print(f"\n### Dry-run ({tag}-pod mesh, {chips} chips)\n")
        print("| arch | shape | compile s | peak GiB/dev | "
              "collective MiB/dev |")
        print("|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} | "
                  f"{r['peak_bytes_per_device']/2**30:.2f} | "
                  f"{r['collective_bytes_per_device']/2**20:.0f} |")
        skips = sum(1 for r in rs if r.get("skipped"))
        print(f"\ncompiled: {len(rows)}; skipped (documented): {skips}")


def roofline_table():
    rs = _load("roofline.json")
    if not rs:
        return
    rows = [r for r in rs if "dominant" in r]
    print("\n### Roofline (single-pod, per step)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL_FLOPS | useful | roofline |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
              f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
              f"{r['dominant']} | {r['model_flops']:.3g} | "
              f"{r['useful_ratio']:.3f} | {r['roofline_frac']:.4f} |")


def perf_tables():
    for name, title in (("hillclimb_granite.json",
                         "Perf cell A: granite-moe train_4k"),
                        ("hillclimb_decode.json",
                         "Perf cell B: decode weight streaming"),
                        ("fedat_mix_isolated.json",
                         "Perf cell C: FedAT cross-tier sync "
                         "(MiB/device/sync by bits)")):
        data = _load(name)
        if not data:
            continue
        print(f"\n### {title}\n")
        if name.startswith("fedat"):
            print("| bits | MiB/device |")
            print("|---|---|")
            for bits, b in data.items():
                print(f"| {bits or 'f32'} | {b/2**20:.1f} |")
            continue
        print("| iteration | C ms | M ms | N ms | dominant | roofline |")
        print("|---|---|---|---|---|---|")
        for tag, r in data.items():
            print(f"| {tag} | {r['compute_s']*1e3:.1f} | "
                  f"{r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} | "
                  f"{r['dominant']} | {r['roofline_frac']:.4f} |")


def main():
    dryrun_tables()
    roofline_table()
    perf_tables()


if __name__ == "__main__":
    main()
