"""Roofline analysis: three terms per (arch x shape) cell on the single-pod
production mesh (EXPERIMENTS.md §Roofline).

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI link_bw

XLA's cost model counts while-loop bodies ONCE, so whole-graph numbers
under-count scanned layers.  This module therefore *composes* the cell cost:

    total = n_calls_layer * cost(one layer)
          + cost(full model, n_layers=1) - 1 * cost(one layer)   [embed/loss]
          (+ n_apps * cost(shared block) for the zamba2 hybrid)

Per-layer costs at the cell's full sequence length would need the inner
chunk-scans unrolled (prohibitive at 32k+), so each layer is lowered with
unrolled scans at S in {512, 1024, 2048} and fitted to the exact cost basis

    cost(S) = c0 + c1 * S + c2 * S * K(S),   K = min(S, window) else S

which is closed-form for linear-scan (SSM/RWKV/MoE), sliding-window and
full quadratic attention alike; the fit is then evaluated at the cell's
true S.  Decode cells have no inner scans and are lowered directly.

Each component is lowered on the single-pod production mesh with the cell's
real shardings, so per-device numbers compose exactly (verified: SPMD
cost_analysis is per-device).  The FedAT cross-tier term (the compressed
pod collective) is measured separately from the multi-pod dry-run.
"""
from __future__ import annotations

import json
import os
from typing import Any, Callable, Dict, Optional

if __name__ == "__main__":  # set BEFORE jax init when run as a script
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import flops as flops_mod
from repro.configs import SHAPES, applicable
from repro.configs.base import ModelConfig
from repro.configs.registry import get_config
from repro.configs.shapes import ShapeConfig
from repro.launch.mesh import (V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_FLOPS,
                               make_production_mesh)
from repro.models import attention as attn_mod
from repro.models import common, lm, mamba2, rwkv6, transformer
from repro.models.common import PSpec
from repro.runtime import sharding as shd
from repro.runtime.hlo import collective_bytes

FIT_S = (512, 1024, 2048)
METRICS = ("flops", "bytes", "coll_bytes")


def _unstack(specs):
    """Drop the leading stacked-layer dim from a spec tree."""
    def f(s: PSpec):
        if s.axes and s.axes[0] == "layers":
            return PSpec(s.shape[1:], s.axes[1:], s.init, s.scale)
        return s
    return jax.tree.map(f, specs, is_leaf=common.is_pspec)


def _cost_of(lowered) -> Dict[str, float]:
    comp = lowered.compile()
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):  # some backends return [dict]
        ca = ca[0] if ca else {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(collective_bytes(comp.as_text())),
    }


def _sub_clip(a, b):
    return {k: max(a[k] - b[k], 0.0) for k in METRICS}


def _kfun(cfg: ModelConfig) -> Callable[[float], float]:
    if cfg.swa_window:
        return lambda s: min(s, cfg.swa_window)
    return lambda s: s


def _fit_eval(points: Dict[int, Dict[str, float]], s_target: int,
              K: Callable[[float], float],
              quadratic: bool = True) -> Dict[str, float]:
    """Per-metric basis.  flops/bytes get the quadratic attention term only
    for components that actually contain attention (``quadratic``) — for
    linear-scan layers (SSM/RWKV backbone) and for collective bytes (always
    activation psums + constant weight gathers) a spurious quadratic
    coefficient would explode x(S_target/S_fit)^2 at extrapolation."""
    ss = sorted(points)
    out = {}
    for m in METRICS:
        y = np.array([points[s][m] for s in ss])
        if m == "coll_bytes" or not quadratic:
            A = np.array([[1.0, s] for s in ss])
            basis_t = np.array([1.0, s_target])
        else:
            A = np.array([[1.0, s, s * K(s)] for s in ss])
            basis_t = np.array([1.0, s_target, s_target * K(s_target)])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        coef = np.maximum(coef, 0.0)
        out[m] = float(coef @ basis_t)
    return out


def _layer_params(cfg: ModelConfig, tp: int, which: str = "layers"):
    if cfg.family == "hybrid":
        from repro.models import zamba2 as z
        sp = z.param_specs(cfg, tp)
        specs = _unstack(sp["backbone"]) if which == "layers" else sp["shared"]
    elif cfg.family == "ssm":
        specs = _unstack(rwkv6.layer_specs(cfg, tp, 1))
    else:
        specs = _unstack(transformer.param_specs(cfg, tp)["layers"])
    abstract = common.shapes_from_specs(specs, jnp.bfloat16)
    shardings = common.shardings_from_specs(specs)
    return abstract, shardings


def _x_sharding():
    return shd.logical_sharding(("batch", None, None))


# ---------------------------------------------------------------------------
# raw per-component lowering at explicit (B, S)
# ---------------------------------------------------------------------------

def _raw_layer_cost(cfg: ModelConfig, mesh, kind: str, which: str,
                    B: int, S: int, cache_len: int) -> Dict[str, float]:
    tp = mesh.shape["model"]
    ccfg = cfg.replace(unroll_scans=True)
    lp, lp_sh = _layer_params(ccfg, tp, which)
    Sx = 1 if kind == "decode" else S
    x = jax.ShapeDtypeStruct((B, Sx, cfg.d_model), jnp.bfloat16)
    positions = jnp.arange(Sx, dtype=jnp.int32)

    hybrid_shared = cfg.family == "hybrid" and which == "shared"
    if cfg.family in lm.TRANSFORMER_FAMILIES or hybrid_shared:
        cax = attn_mod.cache_axes(ccfg, tp)
        c_sh = attn_mod.KVCache(
            k=shd.logical_sharding(cax), v=shd.logical_sharding(cax),
            positions=shd.logical_sharding(("cache_batch", cax[1])))
        if hybrid_shared:
            from repro.models.zamba2 import _shared_block
            blk_train = lambda p, xx: _shared_block(
                ccfg, p, xx, positions, tp, "train")[0]
            blk_prefill = lambda p, xx, c: _shared_block(
                ccfg, p, xx, positions, tp, "prefill", attn_mod.KVCache(*c))
            blk_decode = lambda p, xx, po, c: _shared_block(
                ccfg, p, xx, None, tp, "decode", attn_mod.KVCache(*c), po)
        else:
            blk_train = lambda p, xx: transformer._block_train(
                ccfg, tp, 0, xx, positions, p)[0]
            blk_prefill = lambda p, xx, c: transformer._block_prefill(
                ccfg, tp, 0, xx, positions, p, attn_mod.KVCache(*c))
            blk_decode = lambda p, xx, po, c: transformer._block_decode(
                ccfg, tp, xx, po, p, attn_mod.KVCache(*c))
        if kind == "train":
            def fn(p, xx):
                f = jax.checkpoint(blk_train) if cfg.remat else blk_train
                return jnp.sum(f(p, xx).astype(jnp.float32))
            lowered = jax.jit(jax.grad(fn, argnums=(0, 1)),
                              in_shardings=(lp_sh, _x_sharding())
                              ).lower(lp, x)
        elif kind == "prefill":
            cache = jax.eval_shape(
                lambda: attn_mod.init_cache(ccfg, B, S, tp))
            lowered = jax.jit(blk_prefill,
                              in_shardings=(lp_sh, _x_sharding(),
                                            tuple(c_sh))
                              ).lower(lp, x, tuple(cache))
        else:
            cache = jax.eval_shape(
                lambda: attn_mod.init_cache(ccfg, B, cache_len, tp))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(blk_decode,
                              in_shardings=(lp_sh, _x_sharding(), None,
                                            tuple(c_sh))
                              ).lower(lp, x, pos, tuple(cache))
    elif cfg.family == "hybrid":  # mamba backbone layer
        state = jax.eval_shape(lambda: mamba2.init_state(ccfg, B))
        s_sh = mamba2.MambaState(
            conv=shd.logical_sharding(("cache_batch", None, None)),
            h=shd.logical_sharding(("cache_batch", "tp", None, None)))
        single = kind == "decode"
        blk = lambda p, xx, st: mamba2.block(
            ccfg, p, xx, mamba2.MambaState(*st), tp, single)
        lowered = _lower_block_kind(cfg, blk, kind, lp, lp_sh, x,
                                    tuple(state), tuple(s_sh))
    else:  # rwkv6
        state = jax.eval_shape(lambda: rwkv6.init_state(ccfg, B, tp))
        s_sh = rwkv6.RWKVState(
            tshift=shd.logical_sharding(("cache_batch", None)),
            cshift=shd.logical_sharding(("cache_batch", None)),
            wkv=shd.logical_sharding(("cache_batch", "tp", None, None)))
        single = kind == "decode"
        blk = lambda p, xx, st: rwkv6.block(
            ccfg, p, xx, rwkv6.RWKVState(*st), tp, single)
        lowered = _lower_block_kind(cfg, blk, kind, lp, lp_sh, x,
                                    tuple(state), tuple(s_sh))
    return _cost_of(lowered)


def _lower_block_kind(cfg, blk, kind, lp, lp_sh, x, state, s_sh):
    if kind == "train":
        def fn(p, xx, st):
            f = (jax.checkpoint(lambda pp, xxx: blk(pp, xxx, st)[0])
                 if cfg.remat else (lambda pp, xxx: blk(pp, xxx, st)[0]))
            return jnp.sum(f(p, xx).astype(jnp.float32))
        return jax.jit(jax.grad(fn, argnums=(0, 1)),
                       in_shardings=(lp_sh, _x_sharding(), s_sh)
                       ).lower(lp, x, state)
    return jax.jit(blk, in_shardings=(lp_sh, _x_sharding(), s_sh)
                   ).lower(lp, x, state)


def _raw_full_cost(cfg: ModelConfig, mesh, kind: str, B: int, S: int,
                   cache_len: int) -> Dict[str, float]:
    """Whole model with n_layers=1 (trip-1 loops counted correctly)."""
    tp = mesh.shape["model"]
    overrides = {"n_layers": 1, "unroll_scans": True}
    if cfg.family == "hybrid":
        overrides["attn_every"] = 1
    ccfg = cfg.replace(**overrides)
    params = lm.abstract_params(ccfg, tp, jnp.bfloat16)
    p_sh = jax.tree.map(lambda a: shd.logical_sharding(a),
                        lm.param_axes(ccfg, tp),
                        is_leaf=lambda l: isinstance(l, tuple))
    shp = ShapeConfig("fit", S, B, kind)
    is_ax = lambda l: isinstance(l, tuple) and all(
        x is None or isinstance(x, str) for x in l)
    if kind == "train":
        batch = lm.input_specs(ccfg, shp)
        b_sh = {k: shd.logical_sharding(a)
                for k, a in lm.input_axes(ccfg, shp).items()}
        fn = jax.grad(lambda p, b: lm.loss_fn(ccfg, p, b, tp)[0])
        lowered = jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(params, batch)
    elif kind == "prefill":
        cache = lm.abstract_cache(ccfg, B, S, tp)
        c_sh = jax.tree.map(lambda a: shd.logical_sharding(a),
                            lm.cache_axes_tree(ccfg, tp), is_leaf=is_ax)
        batch = lm.input_specs(ccfg, shp)
        b_sh = {k: shd.logical_sharding(a)
                for k, a in lm.input_axes(ccfg, shp).items()}
        fn = lambda p, b, c: lm.serve_prefill(ccfg, p, b, tp, c)
        lowered = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh)
                          ).lower(params, batch, cache)
    else:
        cache = lm.abstract_cache(ccfg, B, cache_len, tp)
        c_sh = jax.tree.map(lambda a: shd.logical_sharding(a),
                            lm.cache_axes_tree(ccfg, tp), is_leaf=is_ax)
        toks = jax.ShapeDtypeStruct((B,), jnp.int32)
        t_sh = shd.logical_sharding(("batch",))
        fn = lambda p, t, po, c: lm.serve_step(ccfg, p, t, po, tp, c)
        lowered = jax.jit(fn, in_shardings=(p_sh, t_sh, None, c_sh)
                          ).lower(params, toks,
                                  jax.ShapeDtypeStruct((), jnp.int32), cache)
    return _cost_of(lowered)


# ---------------------------------------------------------------------------
# cell composition + roofline terms
# ---------------------------------------------------------------------------

def composed_cell_cost(arch: str, shape_name: str,
                       overrides: Optional[dict] = None,
                       rules_override: Optional[dict] = None
                       ) -> Dict[str, Any]:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape):
        return {"skipped": True, "arch": arch, "shape": shape_name}
    mesh = make_production_mesh(multi_pod=False)
    dp = mesh.shape.get("data", 1)
    rules = dict(rules_override or {})
    if shape.global_batch < dp:
        rules.update({"batch": None, "cache_batch": None})
    rules = rules or None
    kind = shape.kind
    B = shape.global_batch
    if kind == "train" and cfg.microbatch:
        B = max(B // cfg.microbatch, 1)
    K = _kfun(cfg)
    S = shape.seq_len
    napps = cfg.n_layers // cfg.attn_every if cfg.family == "hybrid" else 0

    with mesh, shd.use_mesh(mesh, rules):
        if kind == "decode":
            lcost = _raw_layer_cost(cfg, mesh, kind, "layers", B, 1, S)
            c1 = _raw_full_cost(cfg, mesh, kind, B, 1, S)
            scost = (_raw_layer_cost(cfg, mesh, kind, "shared", B, 1, S)
                     if cfg.family == "hybrid" else None)
        else:
            pts, spts, fpts = {}, {}, {}
            for s_i in FIT_S:
                pts[s_i] = _raw_layer_cost(cfg, mesh, kind, "layers",
                                           B, s_i, s_i)
                if cfg.family == "hybrid":
                    spts[s_i] = _raw_layer_cost(cfg, mesh, kind, "shared",
                                                B, s_i, s_i)
                fpts[s_i] = _raw_full_cost(cfg, mesh, kind, B, s_i, s_i)
            # quadratic-in-S cost only where attention lives: transformer
            # layers and the zamba2 shared block; mamba/rwkv scans are linear
            layer_quad = cfg.family in lm.TRANSFORMER_FAMILIES
            lcost = _fit_eval(pts, S, K, quadratic=layer_quad)
            scost = _fit_eval(spts, S, K, quadratic=True) \
                if cfg.family == "hybrid" else None
            top_pts = {s: _sub_clip(
                fpts[s], pts[s] if not spts else
                {m: pts[s][m] + spts[s][m] for m in METRICS})
                for s in FIT_S}
            c1 = None

        if kind == "decode":
            if scost is not None:
                top = _sub_clip(_sub_clip(c1, lcost), scost)
            else:
                top = _sub_clip(c1, lcost)
        else:
            top = _fit_eval(top_pts, S, K, quadratic=False)

        total = {m: top[m] + cfg.n_layers * lcost[m] +
                 (napps * scost[m] if scost else 0.0) for m in METRICS}
        if kind == "train" and cfg.microbatch:
            total = {k: v * cfg.microbatch for k, v in total.items()}
            per_dev_params = cfg.param_count() / mesh.size
            total["flops"] += 10 * per_dev_params     # AdamW update
            total["bytes"] += 20 * per_dev_params
    return {"arch": arch, "shape": shape_name, "kind": kind,
            "per_layer": lcost, "per_shared": scost, "top": top,
            "total": total, "n_devices": mesh.size}


def roofline_terms(cell: Dict[str, Any], cfg: ModelConfig,
                   shape: ShapeConfig) -> Dict[str, Any]:
    t = cell["total"]
    compute_s = t["flops"] / V5E_PEAK_FLOPS
    memory_s = t["bytes"] / V5E_HBM_BW
    coll_s = t["coll_bytes"] / V5E_ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda kv: kv[1])[0]
    mf = flops_mod.model_flops(cfg, shape)
    hlo_global = t["flops"] * cell["n_devices"]
    bound = max(compute_s, memory_s, coll_s)
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "attn_flops": flops_mod.attention_flops(cfg, shape),
        # roofline fraction: useful model FLOP/s at the bound vs chip peak
        "roofline_frac": (mf / cell["n_devices"] / V5E_PEAK_FLOPS) / bound
        if bound else 0.0,
        "step_time_bound_s": bound,
    }


def analyze(arch: str, shape_name: str, overrides=None,
            rules_override=None) -> Dict[str, Any]:
    cell = composed_cell_cost(arch, shape_name, overrides, rules_override)
    if cell.get("skipped"):
        return cell
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    out = dict(cell)
    out.update(roofline_terms(cell, cfg, SHAPES[shape_name]))
    return out


# ---------------------------------------------------------------------------
# kernel roofline: measured achieved FLOP/s per kernel vs the machine roof
# ---------------------------------------------------------------------------
# The arch x shape cells above are *analytic* (lowered costs on the
# production mesh, never executed).  The kernel roofline is *measured*:
# each kernel-layer entry point runs on this host and its achieved
# FLOP/s is pinned against the classic ceiling min(peak, AI * bw) — peak
# and bandwidth from the v5e datasheet on TPU, calibrated in place on
# anything else (a big matmul and a big stream, so CPU CI numbers are a
# fraction of a *real* roof, not of a TPU constant they can never hit).

def _calibrate_machine(reps: int = 3):
    """(peak FLOP/s, memory bytes/s) for the backend the bench runs on."""
    if jax.default_backend() == "tpu":
        return float(V5E_PEAK_FLOPS), float(V5E_HBM_BW)
    import time
    n = 1024
    a = jnp.ones((n, n), jnp.float32)
    mm = jax.jit(lambda x: x @ x)
    jax.block_until_ready(mm(a))
    best = min(_timed_call(mm, (a,)) for _ in range(reps))
    peak = 2.0 * n ** 3 / best
    big = jnp.ones((16 * 1024 * 1024,), jnp.float32)   # 64 MB stream
    add = jax.jit(lambda x: x + 1.0)
    jax.block_until_ready(add(big))
    best = min(_timed_call(add, (big,)) for _ in range(reps))
    bw = 2.0 * big.nbytes / best                       # read + write
    return peak, bw


def _timed_call(fn, args) -> float:
    import time
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def kernel_roofline(smoke: bool = False):
    """Measure every kernel-layer entry point against the machine roof.

    Returns ``{"machine": {...}, "kernels": [row, ...]}`` where each row
    has the kernel's HLO flops/bytes (cost_analysis of the exact lowered
    call), measured best-of-N wall time, achieved FLOP/s and GB/s, and
    its fraction of the roofline ceiling ``min(peak, AI * bw)`` (compute
    kernels) / of the bandwidth roof (streaming kernels read the
    ``bw_frac`` column).  ``smoke`` halves sizes and reps for CI.
    """
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    S = 128 if smoke else 256
    reps = 2 if smoke else 5
    peak, bw = _calibrate_machine(reps=2 if smoke else 3)

    B, H, KV, hd = 2, 4, 2, 64
    kq, kk, kv2 = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(kv2, (B, S, KV, hd), jnp.float32)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = jnp.repeat(k, H // KV, axis=2).transpose(0, 2, 1, 3) \
        .reshape(B * H, S, hd)

    r = jax.random.normal(key, (4, S, 64), jnp.float32)
    lw = -jnp.exp(jax.random.normal(key, (4, S, 64)))
    u = jax.random.normal(key, (4, 64))
    xs = jax.random.normal(key, (4, S, 64), jnp.float32)
    bm = jax.random.normal(key, (4, S, 32), jnp.float32)
    da = -jnp.abs(jax.random.normal(key, (4, S, 1)))
    flat = jax.random.normal(key, (262_144,), jnp.float32)
    cq, cs = ops.compress(flat, 8)

    impl = ops.default_attention_impl()
    cases = [
        # the flash backend exactly as models/attention routes it here
        (f"attention_flash[{impl}]",
         jax.jit(lambda a, b, c: ops.attention(a, b, c, causal=True)),
         (q, k, v)),
        # the naive materialized oracle: the contrast row
        ("attention_reference",
         jax.jit(lambda a, b, c: ref.attention(a, b, c, causal=True)),
         (qf, kf, kf)),
        ("wkv6", jax.jit(lambda a, b, c, d, e: ops.wkv6(a, b, c, d, e)),
         (r, r, r, lw, u)),
        ("ssd", jax.jit(lambda a, b, c, d: ops.ssd(a, b, c, d)),
         (xs, bm, bm, da)),
        ("codec_compress", jax.jit(lambda a: ops.compress(a, 8)), (flat,)),
        ("codec_decompress",
         jax.jit(lambda a, b: ops.decompress(a, b, (262_144,))), (cq, cs)),
    ]

    rows = []
    for name, fn, args in cases:
        cost = _cost_of(fn.lower(*args))
        jax.block_until_ready(fn(*args))   # compile outside the clock
        dt = min(_timed_call(fn, args) for _ in range(reps))
        flops, nbytes = cost["flops"], cost["bytes"]
        ai = flops / nbytes if nbytes else 0.0
        ceiling = min(peak, ai * bw) if ai else peak
        achieved = flops / dt
        rows.append({
            "kernel": name, "seq_len": S,
            "us": round(dt * 1e6, 1),
            "flops": flops, "bytes": nbytes,
            "arith_intensity": round(ai, 3),
            "achieved_gflops": round(achieved / 1e9, 3),
            "achieved_gbs": round(nbytes / dt / 1e9, 3),
            "roofline_frac": round(achieved / ceiling, 4) if ceiling
            else 0.0,
            "bw_frac": round(nbytes / dt / bw, 4) if bw else 0.0,
        })
    return {
        "machine": {
            "backend": jax.default_backend(),
            "peak_gflops": round(peak / 1e9, 2),
            "mem_bw_gbs": round(bw / 1e9, 2),
            "calibrated": jax.default_backend() != "tpu",
            # the bw roof is a DRAM stream; kernels whose working set
            # fits in cache can legitimately exceed frac 1.0 on CPU
            "note": "min(peak, AI*bw) ceiling; cache-resident kernels "
                    "may exceed 1.0 on calibrated (non-TPU) hosts",
        },
        "kernels": rows,
    }


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="experiments/roofline.json")
    args = ap.parse_args()
    from repro.configs.registry import ARCH_IDS
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    results = []
    for a in archs:
        for s in shapes:
            try:
                r = analyze(a, s)
            except Exception as e:  # noqa: BLE001
                import traceback
                traceback.print_exc()
                r = {"arch": a, "shape": s, "failed": repr(e)[:200]}
            results.append(r)
            if "dominant" in r:
                print(f"[roofline] {a:22s} {s:12s} "
                      f"C={r['compute_s']*1e3:9.2f}ms "
                      f"M={r['memory_s']*1e3:9.2f}ms "
                      f"N={r['collective_s']*1e3:9.2f}ms "
                      f"dom={r['dominant']:10s} "
                      f"useful={r['useful_ratio']:.3f} "
                      f"roofline={r['roofline_frac']:.3f}", flush=True)
            else:
                print(f"[roofline] {a:22s} {s:12s} "
                      f"{'skip' if r.get('skipped') else 'FAILED'}",
                      flush=True)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
