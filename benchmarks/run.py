"""Benchmark harness: one function per paper table/figure + kernel benches.

Prints ``name,us_per_call,derived`` CSV lines (derived = the headline
quantity for that table: accuracy, MB, ratio, ...).  Budget-aware: table
benches use a reduced but structurally faithful setup (synthetic non-IID
data, 40 clients / 5 tiers, the paper's delay bands & dropout).

All FL-run benches are driven through the declarative spec API
(:mod:`repro.api`) — one cached environment per scenario, and every
structured result carries the spec hash that produced it.

  PYTHONPATH=src python -m benchmarks.run           # everything
  PYTHONPATH=src python -m benchmarks.run table1 fig5 kernels
  PYTHONPATH=src python -m benchmarks.run engine engine_scaled \\
      engine_lm engine_sharded --json BENCH_engine.json

``engine_lm`` measures the federated-LM path (``data.model=tiny_lm``
through the model registry) with and without the polyline codec —
events/sec, bytes-on-wire, and a result hash over the accuracy
trajectory — plus the flash-vs-reference attention rows on the
long-sequence ``tiny_lm_long`` (seq_len 128), where the backends
actually separate.

``engine_faults`` measures the fault-plane degradation curve (FedAT at
0/5%/20% fault pressure: churn, poisoned uplinks, a tier blackout) —
events/sec and accuracy per level, with the zero-fault row cross-checked
bitwise against a second run.

``engine_population`` measures the population plane's scale axis
(streaming/gather data path at 1k -> 100k -> 1M simulated clients;
100k under ``--smoke``): events/sec, peak data-plane bytes, and the
flat-memory ratio vs the 1k row, with a 256-client stacked-vs-streaming
parity row cross-checked bitwise.

``roofline`` runs the measured kernel roofline
(benchmarks/roofline.kernel_roofline): per-kernel achieved FLOP/s and
% of the machine roof, into ``JSON_DOC["roofline"]``.  ``--smoke``
shrinks sizes/reps for the CI push workflow.  ``--json`` *merges* into
an existing file (records keyed by strategy/scenario), so
``bench-engine`` and ``bench-roofline`` compose into one
BENCH_engine.json.

``--json PATH`` additionally writes the structured results of the
``engine*`` targets (events/sec, per-event us, fused-step trace counts,
per-strategy spec hashes) so the perf trajectory is machine-readable and
attributable across PRs.

Scale axis: ``engine_scaled`` measures the 512-client workload
(``BENCH_SCALED_CLIENTS`` overrides, e.g. 2048) on the current device
topology; ``engine_sharded`` re-runs it under a host mesh with a forced
multi-device count in a subprocess (the device count is fixed at first
jax init, so the sharded measurement needs its own process) and records
the measured sharded events/sec next to the single-device number.
``--devices N`` forces N host devices for this process (must come from a
fresh process); ``--scaled-mesh NAME`` runs the scaled scenario under a
named mesh (launch/mesh.py grammar) — both are what the subprocess uses.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.fedat import measure_ratio

ROWS: List[str] = []


def emit(name: str, us: float, derived: str):
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _spec(strategy="fedat", *, classes=2, seed=0, n_clients=40, cpr=8,
          total=120, eval_every=15, codec=None, **kwargs):
    """The bench scenario: 40 clients / 5 tiers, paper delay bands &
    dropout, reduced budget."""
    return api.ExperimentSpec(
        data=api.DataSpec(n_clients=n_clients, classes_per_client=classes,
                          samples_per_client=40, image_hw=8, seed=seed),
        tiers=api.TierSpec(n_tiers=5, clients_per_round=cpr, n_unstable=4),
        strategy=api.StrategySpec(name=strategy, kwargs=dict(kwargs)),
        transport=api.TransportSpec(codec=codec),
        engine=api.EngineSpec(total_updates=total, eval_every=eval_every,
                              local_epochs=2))


def _timed(spec):
    """(metrics, us_per_update); env materialization stays outside the
    clock, but the first run over a fresh env pays the one-off fused-step
    compile inside it (as the seed-era benches did) — the ``engine``
    target is the steady-state number, it warms explicitly."""
    run = api.build(spec)
    t0 = time.perf_counter()
    m = run.run().metrics
    us = (time.perf_counter() - t0) * 1e6
    return m, us / spec.engine.total_updates


_BASE_TOTAL, _BASELINE_TOTAL = 120, 60


def table1_accuracy():
    """Table 1: best accuracy + per-client accuracy variance, per method,
    across non-IID levels."""
    for ncls in (2, 4, 10):  # 10 == iid
        m, us = _timed(_spec("fedat", classes=ncls, total=_BASE_TOTAL))
        emit(f"table1/fedat/cls{ncls}", us,
             f"acc={m.best_acc:.3f};var={m.acc_var[-1]:.5f}")
        for name in ("fedavg", "tifl", "fedasync"):
            m, us = _timed(_spec(name, classes=ncls, total=_BASELINE_TOTAL))
            emit(f"table1/{name}/cls{ncls}", us,
                 f"acc={m.best_acc:.3f};var={m.acc_var[-1]:.5f}")


def table2_comm_cost():
    """Table 2: MB transferred to reach a target accuracy (2-class)."""
    target = 0.45
    for name in ("fedat", "fedavg", "tifl", "fedasync"):
        total = _BASE_TOTAL if name == "fedat" else _BASELINE_TOTAL
        m = api.run_spec(_spec(name, total=total)).metrics
        b = m.bytes_to_accuracy(target)
        emit(f"table2/{name}", 0.0,
             f"mb_to_{target}={'%.1f' % (b/1e6) if b else 'n/a'};"
             f"total_mb={(m.bytes_up[-1]+m.bytes_down[-1])/1e6:.1f}")


def fig2_time_to_accuracy():
    """Fig. 2: simulated wall-clock to target accuracy."""
    target = 0.40
    runs = {}
    for name in ("fedat", "fedavg", "tifl", "fedasync"):
        total = 120 if name in ("fedat", "fedasync") else 60
        runs[name] = api.run_spec(
            _spec(name, seed=1, total=total, eval_every=10)).metrics
    tf = runs["fedat"].time_to_accuracy(target)
    for name, m in runs.items():
        t = m.time_to_accuracy(target)
        rel = (t / tf) if (t and tf) else float("nan")
        emit(f"fig2/{name}", 0.0,
             f"sim_s_to_{target}={'%.0f' % t if t else 'n/a'};"
             f"x_vs_fedat={rel:.2f}")


def fig5_precision_tradeoff():
    """Fig. 5: compression precision vs accuracy + bytes (a spec sweep
    over the strategy's precision kwarg)."""
    results = api.sweep(_spec("fedat", seed=2),
                        {"strategy.kwargs.precision": [3, 4, 6, None]})
    for res in results:
        m = res.metrics
        prec = res.spec.strategy.kwargs["precision"]
        total_mb = (m.bytes_up[-1] + m.bytes_down[-1]) / 1e6
        emit(f"fig5/precision_{prec}", 0.0,
             f"acc={m.best_acc:.3f};total_mb={total_mb:.1f}")


def fig6_weighted_aggregation():
    """Fig. 6: Eq. 3 weighted aggregation vs uniform."""
    mw = api.run_spec(_spec("fedat", seed=3, weighted=True)).metrics
    mu = api.run_spec(_spec("fedat", seed=3, weighted=False)).metrics
    emit("fig6/weighted", 0.0, f"acc={mw.best_acc:.3f}")
    emit("fig6/uniform", 0.0, f"acc={mu.best_acc:.3f}")
    emit("fig6/delta", 0.0, f"impr={(mw.best_acc-mu.best_acc):.3f}")


def fig7_participation():
    """Fig. 7 (appendix B.1): client participation level."""
    for cpr in (2, 8):
        mf = api.run_spec(_spec("fedat", seed=4, cpr=cpr)).metrics
        ma = api.run_spec(
            _spec("fedavg", seed=4, cpr=cpr, total=_BASELINE_TOTAL)).metrics
        emit(f"fig7/k{cpr}", 0.0,
             f"fedat={mf.best_acc:.3f};fedavg={ma.best_acc:.3f}")


def codec():
    """Compression ratio of the faithful polyline codec + the TPU codec."""
    rng = np.random.default_rng(0)
    w = {"w": rng.normal(0, 0.05, 100_000).astype(np.float32)}
    for prec in (3, 4, 6):
        t0 = time.perf_counter()
        r = measure_ratio(w, prec)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"codec/polyline_p{prec}", us, f"ratio_vs_f32={1/r:.2f}x")
    from repro.compress import polyline, quantize
    # vectorized vs scalar-reference polyline encoder
    t0 = time.perf_counter()
    polyline.encode_values(w["w"], 4)
    us_vec = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    polyline.encode_values_ref(w["w"], 4)
    us_ref = (time.perf_counter() - t0) * 1e6
    emit("codec/polyline_encode_100k", us_vec,
         f"speedup_vs_ref={us_ref / us_vec:.1f}x")
    x = jnp.asarray(w["w"])
    for bits in (8, 16):
        c = quantize.compress(x, bits)
        ratio = x.size * 4 / quantize.wire_bytes(c)
        emit(f"codec/quantize_int{bits}", 0.0, f"ratio_vs_f32={ratio:.2f}x")


def codec_e2e():
    """FedAT end-to-end per transport codec (engine + strategy + codec)."""
    for codec in ("none", "polyline:4", "quantize8", "quantize16"):
        m, us = _timed(_spec("fedat", seed=5, total=_BASELINE_TOTAL,
                             codec=codec))
        total_mb = (m.bytes_up[-1] + m.bytes_down[-1]) / 1e6
        emit(f"codec_e2e/fedat_{codec.replace(':', '_')}", us,
             f"acc={m.best_acc:.3f};total_mb={total_mb:.1f}")


#: structured results for ``--json`` (filled by the engine target)
JSON_DOC: Dict[str, Any] = {"bench": "engine", "results": []}


def engine():
    """Engine hot-path throughput: events/sec + per-event us per strategy
    on the 40-client bench env.  One warm run amortizes the single fused
    compile, then a timed run measures the steady state; the executor's
    trace counters document that no shape-driven retraces occurred.  Each
    JSON record carries the spec hash of the timed configuration."""
    for name, n in (("fedat", 120), ("fedavg", 60), ("tifl", 60),
                    ("fedasync", 120)):
        spec = _spec(name, seed=6, total=n)
        warm = spec.with_overrides(
            {"engine.total_updates": max(n // 10, 5)})
        api.build(warm).run()  # warm: compile the fused step once
        run = api.build(spec)
        t0 = time.perf_counter()
        run.run()
        dt = time.perf_counter() - t0
        ev_s = n / dt
        emit(f"engine/{name}", dt / n * 1e6, f"events_per_sec={ev_s:.2f}")
        JSON_DOC["results"].append({
            "strategy": name, "total_updates": n,
            "events_per_sec": round(ev_s, 3),
            "us_per_event": round(dt / n * 1e6, 1),
            "spec_hash": spec.hash(),
        })
    env = api.get_env(_spec("fedat", seed=6))
    JSON_DOC["trace_counts"] = {
        "/".join(map(str, k)): v
        for k, v in env.executor().trace_counts.items()}
    JSON_DOC["spec_hashes"] = {r["strategy"]: r["spec_hash"]
                               for r in JSON_DOC["results"]}


#: named mesh for the scaled scenario (set by --scaled-mesh; the
#: engine_sharded subprocess passes "host")
SCALED_MESH: List[str] = [None]


def _scaled_spec(mesh=None):
    """The scale-axis scenario: >= 512 clients, a larger per-round client
    fan-out, reduced budget (the per-event cost is what's measured)."""
    n = int(os.environ.get("BENCH_SCALED_CLIENTS", "512"))
    mesh_spec = api.MeshSpec.from_name(mesh) if mesh else api.MeshSpec()
    return api.ExperimentSpec(
        data=api.DataSpec(n_clients=n, classes_per_client=2,
                          samples_per_client=40, image_hw=8, seed=8),
        tiers=api.TierSpec(n_tiers=5, clients_per_round=32,
                           n_unstable=n // 16),
        strategy=api.StrategySpec(name="fedat"),
        engine=api.EngineSpec(total_updates=12, eval_every=12,
                              local_epochs=1),
        mesh=mesh_spec)


def engine_scaled():
    """Scaled FedAT workload (512+ clients, clients_per_round=32) on the
    current device topology — the measured max-workload point.  Under
    ``--scaled-mesh host`` with forced devices this is the client-sharded
    round step; on one device it is the single-device fused step."""
    mesh = SCALED_MESH[0]
    spec = _scaled_spec(mesh)
    n_updates = spec.engine.total_updates
    warm = spec.with_overrides({"engine.total_updates": 3})
    api.build(warm).run()            # warm: compile the fused step once
    run = api.build(spec)
    t0 = time.perf_counter()
    run.run()
    dt = time.perf_counter() - t0
    env = run.env
    tag = f"scaled_{spec.data.n_clients}" + (f"_{mesh}" if mesh else "")
    emit(f"engine/{tag}", dt / n_updates * 1e6,
         f"events_per_sec={n_updates / dt:.2f};devices={len(jax.devices())}"
         f";data_axis={env.data_axis}")
    JSON_DOC["results"].append({
        "strategy": "fedat", "scenario": tag,
        "n_clients": spec.data.n_clients,
        "clients_per_round": spec.tiers.clients_per_round,
        "mesh": mesh or "single", "n_devices": len(jax.devices()),
        "data_axis": env.data_axis,
        "total_updates": n_updates,
        "events_per_sec": round(n_updates / dt, 3),
        "us_per_event": round(dt / n_updates * 1e6, 1),
        "trace_counts": {"/".join(map(str, k)): v
                         for k, v in env.executor().trace_counts.items()},
        "spec_hash": spec.hash(),
    })


def _lm_spec(codec=None, *, model="tiny_lm", seq_len=16, backend="auto",
             total=24):
    """The federated-LM scenario: tiny_lm (models/registry.py) over
    class-conditional token streams, 24 clients / 3 tiers.  The long-seq
    flash-vs-reference rows pass ``model="tiny_lm_long"``/``seq_len=128``
    and pin ``backend`` explicitly."""
    return api.ExperimentSpec(
        data=api.DataSpec(model=model, n_clients=24,
                          classes_per_client=2, samples_per_client=24,
                          vocab_size=64, seq_len=seq_len,
                          attention_backend=backend, seed=9),
        tiers=api.TierSpec(n_tiers=3, clients_per_round=4, n_unstable=2),
        strategy=api.StrategySpec(name="fedat"),
        transport=api.TransportSpec(codec=codec),
        engine=api.EngineSpec(total_updates=total, eval_every=total // 2,
                              local_epochs=1))


def _run_lm_row(spec, tag, extra=None):
    """Warm + time one federated-LM scenario and append its JSON record
    (spec hash + result hash over the accuracy trajectory); scenarios
    sharing a cached env record only their own trace delta, so every
    record reads "one trace per config" on its own.  Returns the record.
    """
    import hashlib
    n = spec.engine.total_updates
    before = dict(api.get_env(spec).executor().trace_counts)
    warm = spec.with_overrides({"engine.total_updates": 3})
    api.build(warm).run()            # warm: compile the fused step once
    run = api.build(spec)
    t0 = time.perf_counter()
    m = run.run().metrics
    dt = time.perf_counter() - t0
    total_mb = (m.bytes_up[-1] + m.bytes_down[-1]) / 1e6
    emit(f"engine/{tag}", dt / n * 1e6,
         f"events_per_sec={n / dt:.2f};acc={m.best_acc:.3f}"
         f";total_mb={total_mb:.2f}")
    result_hash = hashlib.sha256(
        np.asarray(m.acc, np.float64).tobytes()).hexdigest()[:12]
    rec = {
        "strategy": "fedat", "scenario": tag, "model": spec.data.model,
        "codec": spec.transport.codec or "none",
        "attention_backend": spec.data.attention_backend,
        "seq_len": spec.data.seq_len, "total_updates": n,
        "events_per_sec": round(n / dt, 3),
        "us_per_event": round(dt / n * 1e6, 1),
        "best_acc": round(m.best_acc, 4),
        "bytes_up": m.bytes_up[-1], "bytes_down": m.bytes_down[-1],
        "trace_counts": {
            "/".join(map(str, k)): v - before.get(k, 0)
            for k, v in run.env.executor().trace_counts.items()
            if v - before.get(k, 0)},
        "result_hash": result_hash,
        "spec_hash": spec.hash(),
    }
    rec.update(extra or {})
    JSON_DOC["results"].append(rec)
    return rec


def engine_lm():
    """Federated LM through the registry path: events/sec and
    bytes-on-wire with and without the polyline codec, plus the
    flash-vs-reference attention rows on the long-sequence tiny_lm
    (seq_len 128, where the O(S^2) attention term dominates the client
    step — the short-seq scenario can't separate the backends).  Each
    record carries the spec hash and a result hash so the LM path's
    output is attributable and comparable across PRs."""
    for codec in ("none", "polyline:4"):
        _run_lm_row(_lm_spec(codec), f"lm_{codec.replace(':', '_')}")

    # the attention-backend axis: same long-seq scenario, only the
    # attention path differs; the headline is the events/sec ratio
    total = 8 if SMOKE[0] else 16
    rows = {}
    for backend in ("reference", "flash"):
        spec = _lm_spec(model="tiny_lm_long", seq_len=128,
                        backend=backend, total=total)
        rows[backend] = _run_lm_row(spec, f"lm_long_{backend}")
    speedup = (rows["flash"]["events_per_sec"]
               / rows["reference"]["events_per_sec"])
    emit("engine/lm_long_flash_speedup", 0.0,
         f"x_vs_reference={speedup:.2f}")
    rows["flash"]["speedup_vs_reference"] = round(speedup, 3)


def engine_faults():
    """Fault-plane degradation curve: FedAT on the bench scenario at
    increasing fault pressure — 0 (the zero-fault baseline), 5% client
    churn, and 20% churn + poisoned uplinks + a tier blackout.  Records
    events/sec (the fault plane must not tax the hot loop) and the
    accuracy degradation; the zero-fault row is additionally run twice
    and cross-checked bitwise (trajectory *and* bytes-on-wire), pinning
    the spec-level side of the zero-fault parity contract."""
    total = 20 if SMOKE[0] else 60
    base = _spec("fedat", seed=7, total=total, eval_every=total // 4)
    # windows sit inside the scenario's actual sim-time span (~13-50s of
    # simulated time for 60 updates under the paper delay bands)
    levels = (
        ("faults_0", {}),
        ("faults_5", {"faults.churn_rate": 0.05,
                      "faults.churn_window": [5.0, 45.0],
                      "faults.churn_downtime": 15.0}),
        ("faults_20", {"faults.churn_rate": 0.20,
                       "faults.churn_window": [5.0, 45.0],
                       "faults.churn_downtime": 15.0,
                       "faults.nan_rate": 0.10,
                       "faults.blackouts": 1,
                       "faults.blackout_window": [10.0, 35.0],
                       "faults.blackout_duration": 8.0}),
    )
    for tag, overrides in levels:
        spec = base.with_overrides(overrides) if overrides else base
        warm = spec.with_overrides({"engine.total_updates": 5})
        api.build(warm).run()        # warm: compile the (gated) step once
        run = api.build(spec)
        t0 = time.perf_counter()
        m = run.run().metrics
        dt = time.perf_counter() - t0
        total_mb = (m.bytes_up[-1] + m.bytes_down[-1]) / 1e6
        emit(f"engine/{tag}", dt / total * 1e6,
             f"events_per_sec={total / dt:.2f};acc={m.best_acc:.3f}"
             f";final_acc={m.acc[-1]:.3f};total_mb={total_mb:.1f}")
        rec = {
            "strategy": "fedat", "scenario": tag,
            "total_updates": total,
            "events_per_sec": round(total / dt, 3),
            "us_per_event": round(dt / total * 1e6, 1),
            "best_acc": round(m.best_acc, 4),
            "final_acc": round(m.acc[-1], 4),
            "total_mb": round(total_mb, 3),
            "spec_hash": spec.hash(),
        }
        if tag == "faults_0":
            # the degradation curve's origin doubles as a parity pin
            m2 = api.build(spec).run().metrics
            rec["zero_fault_bitwise"] = (
                m.times == m2.times and m.acc == m2.acc
                and m.bytes_up == m2.bytes_up
                and m.bytes_down == m2.bytes_down)
        JSON_DOC["results"].append(rec)


def _population_spec(n, plane="streaming", total=10):
    """The population-plane scenario: the scaled workload shape
    (clients_per_round=32, 5 tiers) over the indexed population with
    FLGo-style availability/responsiveness processes, at any N."""
    return api.ExperimentSpec(
        data=api.DataSpec(n_clients=n, classes_per_client=2,
                          samples_per_client=24, image_hw=8, seed=8),
        tiers=api.TierSpec(n_tiers=5, clients_per_round=32,
                           n_unstable=max(n // 16, 1)),
        strategy=api.StrategySpec(name="fedat"),
        engine=api.EngineSpec(total_updates=total, eval_every=total,
                              local_epochs=1),
        population=api.PopulationSpec(
            plane=plane, availability="bernoulli:0.9:20",
            responsiveness="lognormal:0.25", eval_clients=64, seed=1))


def engine_population():
    """Population-plane scale axis (DESIGN.md §Population-plane):

    * a parity pin at N=256 — the streaming plane re-run against the
      stacked plane and cross-checked bitwise (trajectory + bytes),
      recorded like ``engine_faults``' zero-fault pin;
    * streaming rows at 1k -> 100k -> 1M clients (100k in ``--smoke``),
      each recording events/sec, the peak data-plane bytes, and the
      flat-memory ratio vs the 1k row (the acceptance bound: within 10%).

    Environments are evicted between rows so a 1M-client population's
    host state doesn't sit under the next row's measurement."""
    # -- parity pin ----------------------------------------------------
    api.clear_env_cache()
    m_stack = api.run_spec(_population_spec(256, plane="stacked")).metrics
    api.clear_env_cache()
    spec = _population_spec(256)
    m_stream = api.run_spec(spec).metrics
    bitwise = (m_stack.times == m_stream.times
               and m_stack.acc == m_stream.acc
               and m_stack.bytes_up == m_stream.bytes_up
               and m_stack.bytes_down == m_stream.bytes_down)
    api.clear_env_cache()
    emit("engine/population_parity_256", 0.0,
         f"stream_bitwise_eq_stacked={bitwise}")
    JSON_DOC["results"].append({
        "strategy": "fedat", "scenario": "population_parity_256",
        "n_clients": 256, "stream_bitwise_eq_stacked": bitwise,
        "spec_hash": spec.hash(),
    })

    # -- scale rows ----------------------------------------------------
    sizes = (1_000, 100_000) if SMOKE[0] else (1_000, 100_000, 1_000_000)
    bytes_1k = None
    for n in sizes:
        spec = _population_spec(n)
        total = spec.engine.total_updates
        warm = spec.with_overrides({"engine.total_updates": 3})
        api.build(warm).run()        # warm: compile the fused step once
        run = api.build(spec)
        t0 = time.perf_counter()
        m = run.run().metrics
        dt = time.perf_counter() - t0
        env = run.env
        peak = env.data_plane_bytes()
        if bytes_1k is None:
            bytes_1k = peak
        ratio = peak / bytes_1k
        tag = f"population_{n}"
        emit(f"engine/{tag}", dt / total * 1e6,
             f"events_per_sec={total / dt:.2f};"
             f"data_plane_mb={peak / 1e6:.2f};flat_vs_1k={ratio:.3f}")
        JSON_DOC["results"].append({
            "strategy": "fedat", "scenario": tag, "n_clients": n,
            "clients_per_round": spec.tiers.clients_per_round,
            "plane": "streaming", "total_updates": total,
            "events_per_sec": round(total / dt, 3),
            "us_per_event": round(dt / total * 1e6, 1),
            "best_acc": round(m.best_acc, 4),
            "data_plane_bytes": int(peak),
            "flat_vs_1k": round(ratio, 4),
            "trace_counts": {"/".join(map(str, k)): v
                             for k, v in env.executor().trace_counts.items()},
            "spec_hash": spec.hash(),
        })
        api.clear_env_cache()   # free the (N,)-sized host state arrays


def _topology_spec(total, lam=0.0, codec=None, seed=9):
    """The topology-plane scenario: 2 regional silos x 2 edges over 32
    clients with WAN delay bands on every link class and a strong
    region skew (silo 1's WAN draws are 4x silo 0's), so the slow silo
    commits genuinely stale Eq. 3 updates — the regime delayed-gradient
    compensation targets.  The update budget is deliberately small: at
    saturation every trajectory converges and the compensation axis
    flattens out."""
    return api.ExperimentSpec(
        data=api.DataSpec(n_clients=32, classes_per_client=2,
                          samples_per_client=24, image_hw=8, seed=seed),
        tiers=api.TierSpec(n_tiers=1, clients_per_round=4, n_unstable=0),
        strategy=api.StrategySpec("fedat"),
        engine=api.EngineSpec(total_updates=total,
                              eval_every=max(total // 4, 1),
                              local_epochs=1),
        topology=api.TopologySpec(
            n_silos=2, edges_per_silo=2,
            delay={"client_edge": (0.5, 1.5), "edge_silo": (1.0, 3.0),
                   "silo_global": (20.0, 60.0)},
            codec=codec or {}, silo_skew=3.0, compensation=lam))


def engine_topology():
    """Topology-plane axis (DESIGN.md §Topology-plane):

    * the degenerate bitwise pin, re-checked on every bench run — a
      1-silo/1-edge zero-delay topology replays the flat FedAT run
      byte-for-byte (trajectory *and* wire bytes);
    * flat vs hierarchical events/sec on the same 32-client workload;
    * the hierarchical row with distinct per-link codecs, recording the
      per-link-class wire bytes (client_edge / edge_silo / silo_global
      are separate ledgers — the WAN hop can be compressed harder);
    * the region-skew accuracy axis: compensation lambda=0 vs 0.8 under
      a 4x-skewed WAN, recording ``comp_beats_uncomp`` (the acceptance
      bound: the compensated run ends at higher final accuracy)."""
    total = 24 if SMOKE[0] else 40

    # -- degenerate bitwise pin ---------------------------------------
    # the same scenario with the topology section dialed back to its
    # defaults (to_config() is None -> the flat engine), and the
    # degenerate *active* topology on top (1 silo, 1 edge, a zero-width
    # delay band keeps the section active without adding any delay)
    flat = _topology_spec(total).with_overrides({
        "topology.n_silos": 1, "topology.edges_per_silo": 1,
        "topology.delay": {}, "topology.silo_skew": 0.0})
    degen = flat.with_overrides({
        "topology.delay.silo_global": [0.0, 0.0]})
    m_flat = api.run_spec(flat).metrics
    m_degen = api.run_spec(degen).metrics
    bitwise = (m_flat.times == m_degen.times and m_flat.acc == m_degen.acc
               and m_flat.bytes_up == m_degen.bytes_up
               and m_flat.bytes_down == m_degen.bytes_down)
    emit("engine/topology_degenerate_pin", 0.0,
         f"degenerate_bitwise_eq_flat={bitwise}")
    JSON_DOC["results"].append({
        "strategy": "fedat", "scenario": "topology_degenerate_pin",
        "degenerate_bitwise_eq_flat": bitwise,
        "spec_hash": degen.hash(),
    })

    # -- flat vs hierarchical events/sec + per-link wire bytes --------
    rows = {}
    for tag, spec in (
        ("topology_flat", flat),
        ("topology_hier", _topology_spec(
            total, codec={"client_edge": "quantize8",
                          "silo_global": "quantize8"})),
    ):
        warm = spec.with_overrides({"engine.total_updates": 5})
        api.build(warm).run()        # warm: compile the step once
        run = api.build(spec)
        t0 = time.perf_counter()
        m = run.run().metrics
        dt = time.perf_counter() - t0
        rec = {
            "strategy": "fedat", "scenario": tag,
            "total_updates": total,
            "events_per_sec": round(total / dt, 3),
            "us_per_event": round(dt / total * 1e6, 1),
            "best_acc": round(m.best_acc, 4),
            "final_acc": round(m.acc[-1], 4),
            "spec_hash": spec.hash(),
        }
        detail = f"events_per_sec={total / dt:.2f};acc={m.best_acc:.3f}"
        if tag == "topology_hier":
            lb = run.strategy.link_bytes
            rec["link_bytes"] = {k: int(v) for k, v in lb.items()}
            detail += ";" + ";".join(
                f"{k}_mb={v / 1e6:.2f}" for k, v in sorted(lb.items()))
        emit(f"engine/{tag}", dt / total * 1e6, detail)
        rows[tag] = rec
        JSON_DOC["results"].append(rec)

    # -- region skew: compensation on vs off --------------------------
    finals = {}
    for lam in (0.0, 0.8):
        spec = _topology_spec(total, lam=lam)
        m = api.run_spec(spec).metrics
        finals[lam] = m.acc[-1]
        tag = f"topology_skew_lam{lam:g}"
        emit(f"engine/{tag}", 0.0,
             f"final_acc={m.acc[-1]:.3f};best_acc={m.best_acc:.3f}")
        JSON_DOC["results"].append({
            "strategy": "fedat", "scenario": tag,
            "total_updates": total, "compensation": lam,
            "final_acc": round(m.acc[-1], 4),
            "best_acc": round(m.best_acc, 4),
            "spec_hash": spec.hash(),
        })
    beats = finals[0.8] > finals[0.0]
    emit("engine/topology_compensation", 0.0,
         f"comp_beats_uncomp={beats}")
    JSON_DOC["results"].append({
        "strategy": "fedat", "scenario": "topology_compensation",
        "final_acc_lam0": round(finals[0.0], 4),
        "final_acc_lam08": round(finals[0.8], 4),
        "comp_beats_uncomp": beats,
    })


def engine_sharded():
    """The scaled scenario under a multi-device host mesh, measured in a
    subprocess with ``--xla_force_host_platform_device_count`` (the only
    way to change the device count after jax initialized here).  Merges
    the child's record into the JSON doc and emits the sharded-vs-single
    throughput ratio when both measurements exist."""
    n_dev = int(os.environ.get("BENCH_SHARD_DEVICES", "2"))
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "sharded.json")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "engine_scaled",
             "--devices", str(n_dev), "--scaled-mesh", "host",
             "--json", out],
            capture_output=True, text=True, timeout=1800,
            env={**os.environ, "JAX_PLATFORMS": "cpu"})
        if proc.returncode != 0:
            emit("engine/sharded", 0.0, "error=subprocess_failed")
            print(proc.stderr[-2000:], file=sys.stderr)
            return
        with open(out) as f:
            child = json.load(f)
    rec = child["results"][-1]
    JSON_DOC["results"].append(rec)
    single = [r for r in JSON_DOC["results"]
              if r.get("scenario", "").startswith("scaled")
              and r.get("mesh") == "single"]
    rel = (rec["events_per_sec"] / single[-1]["events_per_sec"]
           if single else float("nan"))
    emit(f"engine/{rec['scenario']}_d{rec['n_devices']}",
         rec["us_per_event"],
         f"events_per_sec={rec['events_per_sec']:.2f}"
         f";x_vs_single={rel:.2f}")


#: set by --smoke: reduced sizes/reps for the CI push workflow
SMOKE: List[bool] = [False]


def roofline():
    """Kernel roofline (benchmarks/roofline.kernel_roofline): achieved
    FLOP/s and % of the machine roof per kernel-layer entry point,
    recorded into the JSON doc next to the engine rows.  The roof is the
    v5e datasheet on TPU and calibrated in place elsewhere, so CPU CI
    tracks a real ceiling."""
    from benchmarks.roofline import kernel_roofline
    doc = kernel_roofline(smoke=SMOKE[0])
    m = doc["machine"]
    for r in doc["kernels"]:
        emit(f"roofline/{r['kernel']}", r["us"],
             f"gflops={r['achieved_gflops']};"
             f"roofline_frac={r['roofline_frac']};"
             f"bw_frac={r['bw_frac']}")
    emit("roofline/machine", 0.0,
         f"backend={m['backend']};peak_gflops={m['peak_gflops']};"
         f"bw_gbs={m['mem_bw_gbs']}")
    JSON_DOC["roofline"] = doc


def kernels():
    """Kernel microbenches (interpret mode: correctness-path timing only)."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)

    def bench(fn, *args, n=3):
        fn(*args)  # compile
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / n * 1e6

    x = jax.random.normal(key, (262_144,))
    us = bench(lambda a: ops.compress(a, 8), x)
    emit("kernels/codec_compress_256k", us, "interpret=True")
    q, s = ops.compress(x, 8)
    us = bench(lambda a, b: ops.decompress(a, b, (262_144,)), q, s)
    emit("kernels/codec_decompress_256k", us, "interpret=True")

    q4 = jax.random.normal(key, (1, 256, 4, 64))
    k4 = jax.random.normal(key, (1, 256, 4, 64))
    us = bench(lambda a, b, c: ops.flash_attention(a, b, c), q4, k4, k4)
    emit("kernels/flash_attn_256", us, "interpret=True")

    r = jax.random.normal(key, (4, 256, 64))
    lw = -jnp.exp(jax.random.normal(key, (4, 256, 64)))
    u = jax.random.normal(key, (4, 64))
    us = bench(lambda a, b, c, d, e: ops.wkv6(a, b, c, d, e), r, r, r, lw, u)
    emit("kernels/wkv6_256", us, "interpret=True")

    xs = jax.random.normal(key, (4, 256, 64))
    bm = jax.random.normal(key, (4, 256, 32))
    da = -jnp.abs(jax.random.normal(key, (4, 256, 1)))
    us = bench(lambda a, b, c, d: ops.ssd(a, b, c, d), xs, bm, bm, da)
    emit("kernels/ssd_256", us, "interpret=True")


def trainer():
    """Smoke-scale trainer + server throughput (CPU)."""
    from repro.launch import train as train_mod
    t0 = time.perf_counter()
    train_mod.main(["--arch", "qwen2-7b", "--smoke", "--steps", "6",
                    "--ckpt-dir", "/tmp/bench_ck"])
    us = (time.perf_counter() - t0) / 6 * 1e6
    emit("trainer/single_smoke_step", us, "arch=qwen2-7b-smoke")
    from repro.launch import serve as serve_mod
    t0 = time.perf_counter()
    done = serve_mod.main(["--arch", "rwkv6-3b", "--smoke", "--requests",
                           "4", "--slots", "4", "--prompt-len", "16",
                           "--max-new", "8"])
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    emit("server/decode_smoke", dt / max(toks, 1) * 1e6,
         f"tokens={toks};arch=rwkv6-smoke")


ALL = {
    "table1": table1_accuracy,
    "table2": table2_comm_cost,
    "fig2": fig2_time_to_accuracy,
    "fig5": fig5_precision_tradeoff,
    "fig6": fig6_weighted_aggregation,
    "fig7": fig7_participation,
    "codec": codec,
    "codec_e2e": codec_e2e,
    "engine": engine,
    "engine_scaled": engine_scaled,
    "engine_lm": engine_lm,
    "engine_faults": engine_faults,
    "engine_population": engine_population,
    "engine_topology": engine_topology,
    "engine_sharded": engine_sharded,
    "roofline": roofline,
    "kernels": kernels,
    "trainer": trainer,
}

#: targets whose structured results --json records
_JSON_TARGETS = ("engine", "engine_scaled", "engine_lm", "engine_faults",
                 "engine_population", "engine_topology", "engine_sharded",
                 "roofline")


def _write_json(path: str) -> None:
    """Write JSON_DOC, merging into an existing document: new records
    replace old ones with the same (strategy, scenario) key and a fresh
    roofline section replaces the old one, so ``bench-engine`` and
    ``bench-roofline`` compose into one BENCH_engine.json instead of
    clobbering each other's rows."""
    doc = JSON_DOC
    if os.path.exists(path):
        with open(path) as f:
            old = json.load(f)
        key = lambda r: (r.get("strategy"), r.get("scenario"))  # noqa: E731
        fresh = {key(r) for r in doc["results"]}
        merged = [r for r in old.get("results", [])
                  if key(r) not in fresh] + doc["results"]
        for k, v in doc.items():
            if k != "results":
                old[k] = v
        old["results"] = merged
        doc = old
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)


def _pop_flag(argv: List[str], flag: str):
    if flag not in argv:
        return argv, None
    i = argv.index(flag)
    if i + 1 >= len(argv):
        sys.exit(f"usage: benchmarks.run [targets...] {flag} VALUE")
    return argv[:i] + argv[i + 2:], argv[i + 1]


def main() -> None:
    argv, json_path = _pop_flag(sys.argv[1:], "--json")
    argv, devices = _pop_flag(argv, "--devices")
    argv, scaled_mesh = _pop_flag(argv, "--scaled-mesh")
    if "--smoke" in argv:
        argv = [a for a in argv if a != "--smoke"]
        SMOKE[0] = True
    if devices:
        # must run before anything touches the backend: jax is imported
        # above but stays uninitialized until the first device query
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={devices}").strip()
    if scaled_mesh:
        SCALED_MESH[0] = scaled_mesh
    which = argv or [t for t in ALL if t != "engine_sharded"]
    if json_path and not any(t in _JSON_TARGETS for t in which):
        sys.exit(f"--json records the structured targets "
                 f"{_JSON_TARGETS}; add one to the requested targets")
    print("name,us_per_call,derived")
    for name in which:
        ALL[name]()
    if json_path:
        _write_json(json_path)
        print(f"wrote {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
