"""Serving-plane benchmark: latency under open-loop load, from real
federated checkpoints.

Each row trains a federated LM through the spec API, checkpoints it
(spec-hash sidecar), resolves the checkpoint back through
``repro.serve.load_checkpoint``, and serves a deterministic Poisson
request stream with the continuous-batching engine — so the measured
path is exactly the production path: no params are handed across in
memory.  Reported per row: p50/p95/p99 request latency, TTFT, queueing
delay, tok/s, and the engine's trace counts (the one-trace-per-config
contract, visible in the perf record).

Load levels: a closed burst (``rate=0``, every request queued at t=0 —
max slot pressure) and an open-loop Poisson stream (arrival gaps
independent of service time — the no-coordinated-omission latency
number).  A random-init zoo decoder row (``from_checkpoint: false``)
covers the non-toy cache layouts (GQA + tied embeddings).

  PYTHONPATH=src python -m benchmarks.serve_bench --json BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.serve_bench --smoke   # CI push
"""
from __future__ import annotations

import json
import sys
import tempfile
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro import api
from repro.serve import (ServeEngine, ServeSpec, load_checkpoint,
                         make_requests, report)

SMOKE = [False]


def _lm_spec(model: str, seq_len: int, total: int) -> api.ExperimentSpec:
    """The federated training run whose checkpoint gets served."""
    return api.ExperimentSpec().with_overrides({
        "data.model": model, "data.seq_len": seq_len,
        "data.n_clients": 8, "data.samples_per_client": 12,
        "tiers.n_tiers": 2, "tiers.clients_per_round": 2,
        "tiers.n_unstable": 0, "engine.local_epochs": 1,
        "engine.total_updates": total,
        "engine.eval_every": max(total // 2, 1),
    }).validate()


def _serve_row(tag: str, cfg, params, serve_spec: ServeSpec, *,
               rate: float, n_requests: int,
               spec_hash: Optional[str] = None,
               step: Optional[int] = None) -> Dict[str, Any]:
    reqs = make_requests(n_requests, rate, serve_spec.prefill_len,
                         serve_spec.max_new, cfg.vocab_size,
                         seed=serve_spec.seed)
    engine = ServeEngine(cfg, params, serve_spec)
    done = engine.run(reqs)
    rep = report(done)
    rec: Dict[str, Any] = {
        "scenario": tag, "arch": cfg.name, "rate_req_s": rate,
        "slots": serve_spec.slots, "max_new": serve_spec.max_new,
        "from_checkpoint": spec_hash is not None,
        "traces": dict(engine.trace_counts),
    }
    if spec_hash is not None:
        rec.update(spec_hash=spec_hash, step=step)
    rec.update({k: (round(v, 5) if isinstance(v, float) else v)
                for k, v in rep.items()})
    print(f"{tag},rate={rate:g},tok_per_s={rep['tok_per_s']:.1f},"
          f"p50={rep['latency_p50_s']:.3f}s,p95={rep['latency_p95_s']:.3f}s,"
          f"p99={rep['latency_p99_s']:.3f}s", flush=True)
    return rec


def _checkpointed_rows(results: List[Dict[str, Any]]) -> None:
    total = 2 if SMOKE[0] else 6
    n_req = 8 if SMOKE[0] else 24
    max_new = 8 if SMOKE[0] else 16
    rate = 25.0 if SMOKE[0] else 10.0

    with tempfile.TemporaryDirectory() as d:
        spec = _lm_spec("tiny_lm", seq_len=16, total=total)
        t0 = time.perf_counter()
        api.build(spec).run(checkpoint_dir=d)
        loaded = load_checkpoint(d, expect_spec=spec)
        print(f"# tiny_lm trained+checkpointed in "
              f"{time.perf_counter() - t0:.1f}s (spec {loaded.spec_hash})",
              flush=True)
        sspec = ServeSpec(slots=4, max_len=80, prefill_len=16,
                          max_new=max_new)
        # two load levels over the same checkpoint
        for r in (0.0, rate):
            results.append(_serve_row(
                "serve/tiny_lm", loaded.config, loaded.lm_params, sspec,
                rate=r, n_requests=n_req, spec_hash=loaded.spec_hash,
                step=loaded.step))

    with tempfile.TemporaryDirectory() as d:
        spec = _lm_spec("tiny_lm_long", seq_len=128, total=total)
        api.build(spec).run(checkpoint_dir=d)
        loaded = load_checkpoint(d, expect_spec=spec)
        sspec = ServeSpec(slots=4, max_len=128, prefill_len=32,
                          max_new=max_new)
        results.append(_serve_row(
            "serve/tiny_lm_long", loaded.config, loaded.lm_params, sspec,
            rate=rate, n_requests=max(n_req // 2, 4),
            spec_hash=loaded.spec_hash, step=loaded.step))


def _zoo_row(results: List[Dict[str, Any]]) -> None:
    """One zoo decoder (GQA + SWA-free dense stack) at smoke scale,
    random-init: the cache-layout coverage row, not a checkpoint row."""
    from repro.configs.registry import get_smoke_config
    from repro.models import lm

    cfg = get_smoke_config("qwen2-7b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0), 1, jnp.float32)
    sspec = ServeSpec(slots=4, max_len=64, prefill_len=16,
                      max_new=8 if SMOKE[0] else 16)
    results.append(_serve_row("serve/qwen2-7b-smoke", cfg, params, sspec,
                              rate=0.0,
                              n_requests=6 if SMOKE[0] else 12))


def main(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    argv = list(sys.argv[1:] if argv is None else argv)
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        json_path = argv[i + 1]
        del argv[i:i + 2]
    if "--smoke" in argv:
        argv.remove("--smoke")
        SMOKE[0] = True
    if argv:
        sys.exit(f"unknown args {argv}; usage: benchmarks.serve_bench "
                 f"[--smoke] [--json PATH]")

    print("scenario,rate,tok_per_s,p50,p95,p99")
    results: List[Dict[str, Any]] = []
    _checkpointed_rows(results)
    _zoo_row(results)
    doc = {"bench": "serve", "smoke": SMOKE[0], "results": results}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {json_path}", file=sys.stderr)
    return doc


if __name__ == "__main__":
    main()
