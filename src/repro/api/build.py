"""Materialize and execute :class:`~repro.api.spec.ExperimentSpec` runs.

``build(spec)`` turns the declarative spec into a :class:`Run` handle —
``(SimEnv, ServerStrategy, EngineConfig)`` wired together — with the
environment drawn from a process-wide cache keyed on the spec's
environment hash, so sweeping the strategy/codec/budget plane over one
scenario reuses a single materialized environment (and its compiled
fused-round steps).  ``Run.run()`` executes the event loop and returns a
:class:`Result` carrying the metrics, the spec echo, and the spec hash
for provenance; ``sweep()`` expands a cartesian grid of dotted-path
overrides into tagged runs.
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
from typing import Any, Callable, Dict, Iterable, List, Optional

from repro.api.spec import ExperimentSpec, SpecError
from repro.core import strategies
from repro.core.engine import EngineConfig, ServerStrategy, run_engine
from repro.core.scheduler import Metrics
from repro.core.simulation import SimEnv

#: env_hash -> SimEnv; one materialized environment per (data, tiers,
#: local-training) configuration, shared across strategy/codec sweeps
_ENV_CACHE: Dict[str, SimEnv] = {}


def clear_env_cache() -> None:
    """Drop all cached environments (frees device-resident train stacks)."""
    _ENV_CACHE.clear()


def get_env(spec: ExperimentSpec) -> SimEnv:
    """The cached environment for a spec's (data, tiers, local, mesh)
    section.  Build-time configuration errors (e.g. a 'host' mesh whose
    runtime data-axis size does not divide ``clients_per_round`` — only
    knowable once the device count is) surface as :class:`SpecError`."""
    key = spec.env_hash()
    if key not in _ENV_CACHE:
        try:
            _ENV_CACHE[key] = SimEnv(spec.to_sim_config())
        except ValueError as e:
            # chained: a ValueError here is a build-time configuration
            # problem (mesh divisibility, device count), but keep the
            # original traceback in case something deeper raised it
            raise SpecError(str(e)) from e
    return _ENV_CACHE[key]


def _make_strategy(spec: ExperimentSpec) -> ServerStrategy:
    factory = strategies.STRATEGIES[spec.strategy.name]
    kwargs = dict(spec.strategy.kwargs)
    params = inspect.signature(factory).parameters
    if "codec" in params:
        kwargs.setdefault("codec", spec.transport.codec)
    elif spec.transport.codec is not None:
        accepting = sorted(
            n for n, f in strategies.STRATEGIES.items()
            if "codec" in inspect.signature(f).parameters)
        raise SpecError(
            f"strategy {spec.strategy.name!r} does not take a transport "
            f"codec; codec-capable strategies: {accepting}")
    return factory(**kwargs)


@dataclasses.dataclass
class Result:
    """One finished run: metrics + the exact configuration that made them."""
    spec: ExperimentSpec
    spec_hash: str
    metrics: Metrics
    tag: str = ""

    def summary(self) -> Dict[str, Any]:
        s = self.metrics.summary()
        s["spec_hash"] = self.spec_hash
        if self.tag:
            s["tag"] = self.tag
        return s


@dataclasses.dataclass
class Run:
    """A materialized experiment, ready to execute (repeatable: each
    ``run()`` restarts the engine from the bound strategy's fresh state)."""
    spec: ExperimentSpec
    env: SimEnv
    strategy: ServerStrategy
    cfg: EngineConfig
    tag: str = ""

    def run(self, on_eval: Optional[Callable[[dict], None]] = None
            ) -> Result:
        """Execute the event loop; ``on_eval`` streams each recorded eval
        point (dict with time/round/acc/acc_var/bytes_up/bytes_down)."""
        metrics = run_engine(self.env, self.strategy, self.cfg,
                             on_record=on_eval)
        return Result(spec=self.spec, spec_hash=self.spec.hash(),
                      metrics=metrics, tag=self.tag)


def build(spec: ExperimentSpec, env: Optional[SimEnv] = None) -> Run:
    """Validate the spec and materialize ``(SimEnv, strategy, EngineConfig)``.

    ``env`` injects an already-built environment (the legacy ``run_*``
    shims use this); when provided it *overrides* the spec's data/tiers
    materialization — the caller vouches that it matches.
    """
    spec.validate()
    if env is None:
        env = get_env(spec)
    return Run(
        spec=spec, env=env, strategy=_make_strategy(spec),
        cfg=EngineConfig(total_updates=spec.engine.total_updates,
                         eval_every=spec.engine.eval_every,
                         seed=spec.engine.seed,
                         retier_every=spec.tiers.retier_every,
                         retier_drift=spec.tiers.retier_drift))


def run_spec(spec: ExperimentSpec, env: Optional[SimEnv] = None,
             on_eval: Optional[Callable[[dict], None]] = None) -> Result:
    """Build + run in one call."""
    return build(spec, env=env).run(on_eval=on_eval)


def sweep(base_spec: ExperimentSpec, grid: Dict[str, Iterable[Any]],
          on_result: Optional[Callable[[Result], None]] = None
          ) -> List[Result]:
    """Cartesian expansion of a dotted-path override grid into tagged runs.

        sweep(spec, {"strategy.name": ["fedat", "fedavg"],
                     "transport.codec": ["none", "quantize8"]})

    Axis order follows the grid's insertion order; every combination is
    validated before any run executes (a typo fails fast, not mid-sweep).
    Runs sharing a (data, tiers, local) section reuse one cached
    environment.  ``on_result`` streams each finished :class:`Result`.
    """
    if not grid:
        raise SpecError("sweep grid is empty; pass at least one "
                        "dotted-path axis, e.g. {'strategy.name': [...]}")
    axes = [(path, list(values)) for path, values in grid.items()]
    for path, values in axes:
        if not values:
            raise SpecError(f"sweep axis {path!r} has no values")
    combos = list(itertools.product(*(vals for _, vals in axes)))
    runs = []
    for combo in combos:
        overrides = {path: v for (path, _), v in zip(axes, combo)}
        spec = base_spec.with_overrides(overrides)
        spec.validate()
        tag = ",".join(f"{path}={v}" for path, v in overrides.items())
        runs.append((spec, tag))
    results = []
    for spec, tag in runs:
        run = build(spec)
        run.tag = tag
        res = run.run()
        if on_result is not None:
            on_result(res)
        results.append(res)
    return results
