"""Materialize and execute :class:`~repro.api.spec.ExperimentSpec` runs.

``build(spec)`` turns the declarative spec into a :class:`Run` handle —
``(SimEnv, ServerStrategy, EngineConfig)`` wired together — with the
environment drawn from a process-wide cache keyed on the spec's
environment hash, so sweeping the strategy/codec/budget plane over one
scenario reuses a single materialized environment (and its compiled
fused-round steps).  ``Run.run()`` executes the event loop and returns a
:class:`Result` carrying the metrics, the spec echo, and the spec hash
for provenance; ``sweep()`` expands a cartesian grid of dotted-path
overrides into tagged runs.

Checkpointing: ``Run.run(checkpoint_dir=...)`` persists the final global
params (checkpoint/ckpt.py: atomic, integrity-hashed) next to a
``spec.json`` carrying the producing spec and its hash;
``build(spec, resume_from=dir)`` restores those params as the run's
initial model **iff** the saved spec hash matches the current spec's
(mismatch is an actionable :class:`SpecError` — results must stay
attributable to exactly one configuration).  Independently, a spec with
``faults.checkpoint_every > 0`` persists *full engine snapshots* under
``<checkpoint_dir>/engine`` as the run progresses, and
``Run.run(resume_engine=True)`` replays the remainder of a killed run
bitwise (DESIGN.md §Fault-plane) — same hash guard, same SpecError.
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
import json
import os
from typing import Any, Callable, Dict, Iterable, List, Optional

from repro.api.spec import ExperimentSpec, FaultSpec, SpecError
from repro.core import faults as faults_mod
from repro.core import strategies
from repro.core.engine import EngineConfig, ServerStrategy, run_engine
from repro.core.scheduler import Metrics
from repro.core.simulation import SimEnv

#: env_hash -> SimEnv; one materialized environment per (data, tiers,
#: local-training) configuration, shared across strategy/codec sweeps
_ENV_CACHE: Dict[str, SimEnv] = {}


def clear_env_cache() -> None:
    """Drop all cached environments (frees device-resident train stacks)."""
    _ENV_CACHE.clear()


def get_env(spec: ExperimentSpec) -> SimEnv:
    """The cached environment for a spec's (data, tiers, local, mesh)
    section.  Build-time configuration errors (e.g. a 'host' mesh whose
    runtime data-axis size does not divide ``clients_per_round`` — only
    knowable once the device count is) surface as :class:`SpecError`."""
    key = spec.env_hash()
    if key not in _ENV_CACHE:
        try:
            _ENV_CACHE[key] = SimEnv(spec.to_sim_config())
        except ValueError as e:
            # chained: a ValueError here is a build-time configuration
            # problem (mesh divisibility, device count), but keep the
            # original traceback in case something deeper raised it
            raise SpecError(str(e)) from e
    return _ENV_CACHE[key]


def _make_strategy(spec: ExperimentSpec) -> ServerStrategy:
    factory = strategies.STRATEGIES[spec.strategy.name]
    kwargs = dict(spec.strategy.kwargs)
    params = inspect.signature(factory).parameters
    if "codec" in params:
        kwargs.setdefault("codec", spec.transport.codec)
    elif spec.transport.codec is not None:
        accepting = sorted(
            n for n, f in strategies.STRATEGIES.items()
            if "codec" in inspect.signature(f).parameters)
        raise SpecError(
            f"strategy {spec.strategy.name!r} does not take a transport "
            f"codec; codec-capable strategies: {accepting}")
    return factory(**kwargs)


def _fault_config(fs: FaultSpec) -> Optional[faults_mod.FaultConfig]:
    """Engine-plane fault knobs from the spec's ``faults`` section, or
    None when every knob is off — a zero-fault spec must produce an
    EngineConfig identical to the pre-fault-plane engine (the engine-
    parity oracle pins this).  Churn is *not* here: it shapes client
    availability, so it rides the environment (``to_sim_config``)."""
    fc = faults_mod.FaultConfig(
        blackouts=fs.blackouts,
        blackout_duration=fs.blackout_duration,
        blackout_window=tuple(fs.blackout_window),
        nan_rate=fs.nan_rate,
        update_clip=fs.update_clip,
        checkpoint_every=fs.checkpoint_every,
        seed=fs.seed)
    return fc if fc.active else None


def _engine_ckpt_dir(checkpoint_dir: str, spec: ExperimentSpec,
                     resume: bool) -> str:
    """The engine-state checkpoint directory under ``checkpoint_dir``,
    guarded by a spec-hash sidecar: resuming an engine snapshot under a
    *different* spec would silently splice two configurations into one
    trajectory, so a mismatch is an actionable :class:`SpecError`."""
    from repro import checkpoint as ckpt
    eng = os.path.join(checkpoint_dir, "engine")
    os.makedirs(eng, exist_ok=True)
    try:
        saved = ckpt.read_sidecar(eng)
    except FileNotFoundError:
        if resume:
            raise SpecError(
                f"resume_engine=True but {eng!r} has no {ckpt.SIDECAR} — "
                f"nothing was ever checkpointed there (run with "
                f"checkpoint_dir= and faults.checkpoint_every > 0 first)")
        ckpt.write_sidecar(eng, {"spec_hash": spec.hash(),
                                 "spec": spec.to_dict()})
        return eng
    if saved.get("spec_hash") != spec.hash():
        raise SpecError(
            f"engine checkpoint dir {eng!r} holds snapshots written by "
            f"spec {saved.get('spec_hash')} but the current spec hashes "
            f"to {spec.hash()}; point checkpoint_dir somewhere fresh or "
            f"load the matching spec from "
            f"{os.path.join(eng, ckpt.SIDECAR)!r}")
    return eng


@dataclasses.dataclass
class Result:
    """One finished run: metrics + the exact configuration that made them."""
    spec: ExperimentSpec
    spec_hash: str
    metrics: Metrics
    tag: str = ""

    def summary(self) -> Dict[str, Any]:
        s = self.metrics.summary()
        s["spec_hash"] = self.spec_hash
        if self.tag:
            s["tag"] = self.tag
        return s


@dataclasses.dataclass
class Run:
    """A materialized experiment, ready to execute (repeatable: each
    ``run()`` restarts the engine from the bound strategy's fresh state).

    ``initial_params`` (set by ``build(resume_from=...)``) replaces the
    environment's seeded model init for the duration of the run —
    strategies copy their server state at bind time, and the original
    ``params0`` is restored afterwards so the cached environment stays
    reproducible for other runs.
    """
    spec: ExperimentSpec
    env: SimEnv
    strategy: ServerStrategy
    cfg: EngineConfig
    tag: str = ""
    initial_params: Optional[Any] = None

    def run(self, on_eval: Optional[Callable[[dict], None]] = None,
            checkpoint_dir: Optional[str] = None,
            resume_engine: bool = False) -> Result:
        """Execute the event loop; ``on_eval`` streams each recorded eval
        point (dict with time/round/acc/acc_var/bytes_up/bytes_down).
        ``checkpoint_dir`` saves the final global params + the producing
        spec (hash-stamped) there, resumable via
        ``build(spec, resume_from=checkpoint_dir)``.  With
        ``faults.checkpoint_every > 0`` it additionally persists full
        engine snapshots under ``<checkpoint_dir>/engine``;
        ``resume_engine=True`` restores the newest one and replays the
        rest of the run to a bitwise-identical trajectory (the crash-
        resume path, DESIGN.md §Fault-plane)."""
        eng_dir = None
        if checkpoint_dir is not None and self.spec.faults.checkpoint_every:
            eng_dir = _engine_ckpt_dir(checkpoint_dir, self.spec,
                                       resume_engine)
        elif resume_engine:
            raise SpecError(
                "resume_engine=True needs checkpoint_dir= and "
                "faults.checkpoint_every > 0 — there is no engine "
                "snapshot to resume from otherwise")
        params0 = self.env.params0
        if self.initial_params is not None:
            self.env.params0 = self.initial_params
        try:
            metrics = run_engine(self.env, self.strategy, self.cfg,
                                 on_record=on_eval,
                                 checkpoint_dir=eng_dir,
                                 resume=resume_engine)
        finally:
            self.env.params0 = params0
        if checkpoint_dir is not None:
            save_checkpoint(checkpoint_dir, self.spec,
                            self.strategy.global_params(),
                            step=self.cfg.total_updates)
        return Result(spec=self.spec, spec_hash=self.spec.hash(),
                      metrics=metrics, tag=self.tag)


def save_checkpoint(directory: str, spec: ExperimentSpec, params: Any,
                    step: int) -> None:
    """Final-params checkpoint (checkpoint/ckpt.py) + spec provenance
    sidecar; blocking write so the caller can exit immediately after.

    The directory holds exactly one spec's checkpoint: stale steps left
    by earlier runs are cleared first — otherwise the manager's
    keep-last-k GC (which prunes by ascending step number) could delete
    the step being written when a reused directory holds higher-numbered
    steps from a previous spec.
    """
    import shutil
    from repro import checkpoint as ckpt
    mgr = ckpt.CheckpointManager(directory)
    for s in mgr.all_steps():
        if s != step:
            shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                          ignore_errors=True)
    mgr.save(step, {"params": params}, blocking=True)
    # "step" binds the sidecar to the exact step it describes: the
    # manager keeps the last k steps, so a reused directory may hold
    # stale steps written by other specs
    ckpt.write_sidecar(directory, {"spec_hash": spec.hash(), "step": step,
                                   "spec": spec.to_dict()})


def _load_checkpoint(directory: str, spec: ExperimentSpec,
                     env: SimEnv) -> Any:
    """Restore params for ``spec`` from ``directory``; spec-hash mismatch
    (or a missing/corrupt checkpoint) is an actionable SpecError."""
    from repro import checkpoint as ckpt
    try:
        saved = ckpt.read_sidecar(directory)
    except FileNotFoundError:
        raise SpecError(
            f"no {ckpt.SIDECAR} in checkpoint dir {directory!r}; expected "
            f"a checkpoint written by Run.run(checkpoint_dir=...)")
    except (OSError, json.JSONDecodeError) as e:
        raise SpecError(f"unreadable {ckpt.SIDECAR} in checkpoint dir "
                        f"{directory!r}: {e}") from e
    if saved.get("spec_hash") != spec.hash():
        raise SpecError(
            f"checkpoint {directory!r} was written by spec "
            f"{saved.get('spec_hash')} but the current spec hashes to "
            f"{spec.hash()}; load the matching spec from its "
            f"{ckpt.SIDECAR} (api.ExperimentSpec.from_dict(doc['spec'])) "
            f"or point resume_from at a checkpoint of this spec")
    try:
        # restore the exact step the sidecar describes — never "latest",
        # which in a reused directory could be another spec's params
        state, _ = ckpt.CheckpointManager(directory).restore(
            like={"params": env.params0}, step=saved.get("step"))
    except FileNotFoundError as e:
        raise SpecError(f"checkpoint dir {directory!r} has a spec.json "
                        f"but no restorable step "
                        f"{saved.get('step')}: {e}") from e
    return state["params"]


def build(spec: ExperimentSpec, env: Optional[SimEnv] = None,
          resume_from: Optional[str] = None) -> Run:
    """Validate the spec and materialize ``(SimEnv, strategy, EngineConfig)``.

    ``env`` injects an already-built environment (the legacy ``run_*``
    shims use this); when provided it *overrides* the spec's data/tiers
    materialization — the caller vouches that it matches.
    ``resume_from`` restores a ``Run.run(checkpoint_dir=...)`` checkpoint
    as the initial model (spec hash must match).
    """
    spec.validate()
    if env is None:
        env = get_env(spec)
    initial = (None if resume_from is None
               else _load_checkpoint(resume_from, spec, env))
    return Run(
        spec=spec, env=env, strategy=_make_strategy(spec),
        cfg=EngineConfig(total_updates=spec.engine.total_updates,
                         eval_every=spec.engine.eval_every,
                         seed=spec.engine.seed,
                         retier_every=spec.tiers.retier_every,
                         retier_drift=spec.tiers.retier_drift,
                         faults=_fault_config(spec.faults)),
        initial_params=initial)


def run_spec(spec: ExperimentSpec, env: Optional[SimEnv] = None,
             on_eval: Optional[Callable[[dict], None]] = None) -> Result:
    """Build + run in one call."""
    return build(spec, env=env).run(on_eval=on_eval)


def sweep(base_spec: ExperimentSpec, grid: Dict[str, Iterable[Any]],
          on_result: Optional[Callable[[Result], None]] = None
          ) -> List[Result]:
    """Cartesian expansion of a dotted-path override grid into tagged runs.

        sweep(spec, {"strategy.name": ["fedat", "fedavg"],
                     "transport.codec": ["none", "quantize8"]})

    Axis order follows the grid's insertion order; every combination is
    validated before any run executes (a typo fails fast, not mid-sweep).
    Runs sharing a (data, tiers, local) section reuse one cached
    environment.  ``on_result`` streams each finished :class:`Result`.
    """
    if not grid:
        raise SpecError("sweep grid is empty; pass at least one "
                        "dotted-path axis, e.g. {'strategy.name': [...]}")
    axes = [(path, list(values)) for path, values in grid.items()]
    for path, values in axes:
        if not values:
            raise SpecError(f"sweep axis {path!r} has no values")
    combos = list(itertools.product(*(vals for _, vals in axes)))
    runs = []
    for combo in combos:
        overrides = {path: v for (path, _), v in zip(axes, combo)}
        spec = base_spec.with_overrides(overrides)
        spec.validate()
        tag = ",".join(f"{path}={v}" for path, v in overrides.items())
        runs.append((spec, tag))
    results = []
    for spec, tag in runs:
        run = build(spec)
        run.tag = tag
        res = run.run()
        if on_result is not None:
            on_result(res)
        results.append(res)
    return results
