"""Experiment CLI over the declarative spec API.

    # one run: paper defaults + dotted-path overrides
    PYTHONPATH=src python -m repro.api.cli \
        --set data.n_clients=40 --set strategy.name=fedat \
        --set transport.codec=quantize8

    # a spec file + a cartesian sweep (strategy x codec), results to JSON
    PYTHONPATH=src python -m repro.api.cli --spec exp.json \
        --sweep strategy.name=fedat,fedavg \
        --sweep transport.codec=none,quantize8 --out results.json

``--set PATH=VALUE`` applies one override; ``--sweep PATH=V1,V2,...``
adds a grid axis.  Values parse as JSON when possible (``null`` -> None,
``false`` -> False, numbers), else as strings.  ``--out`` writes one
record per run: tag, spec hash, full spec echo, summary, and the eval
trajectory — enough to reproduce or re-plot any run.

Models come from the registry (``models/registry.py``): ``--set
data.model=tiny_lm`` runs a federated LM over token streams through the
same engine/codec/mesh stack (``data.task=image|text`` still works as a
deprecated alias for the paper models).  ``--checkpoint-dir`` saves the
final params + spec hash after a single run; ``--resume-from`` restores
such a checkpoint as the initial model (the saved spec hash must match).
With ``faults.checkpoint_every > 0`` the run also snapshots full engine
state under ``<checkpoint-dir>/engine``, and ``--resume`` replays a
killed run from the newest snapshot to a bitwise-identical trajectory.

Serving: ``repro.api.cli serve --resume-from DIR`` loads a
``--checkpoint-dir`` checkpoint (spec-hash verified against its
``spec.json`` sidecar), rebuilds the registry model from the embedded
spec, and serves it with the continuous-batching engine under open-loop
Poisson load (``--rate``), printing p50/p95/p99 latency and tok/s
(``--out`` writes the full report as JSON).

Client-sharded execution: ``--set mesh.kind=host`` runs the fused round
step sharded over however many local devices exist (force N CPU devices
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before*
launching; jax reads it at first init).  ``tiers.clients_per_round`` must
be a multiple of the mesh's data-axis size — validation says so with the
nearest valid value.  See docs/SPEC.md for the full field reference.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

from repro import api


def _parse_value(s: str) -> Any:
    try:
        return json.loads(s)
    except json.JSONDecodeError:
        return s


def _parse_assignment(arg: str, flag: str) -> tuple:
    path, eq, val = arg.partition("=")
    if not eq or not path:
        raise SystemExit(f"{flag} expects PATH=VALUE, got {arg!r}")
    return path, val


def _result_record(res: api.Result) -> Dict[str, Any]:
    m = res.metrics
    return {
        "tag": res.tag, "spec_hash": res.spec_hash,
        "spec": res.spec.to_dict(), "summary": res.summary(),
        "trajectory": {
            "times": m.times, "rounds": m.rounds, "acc": m.acc,
            "acc_var": m.acc_var, "bytes_up": m.bytes_up,
            "bytes_down": m.bytes_down,
        },
    }


def _print_row(res: api.Result) -> None:
    s = res.metrics.summary()
    print(f"  {res.tag or '(single run)':48s} {res.spec_hash}  "
          f"acc={s['best_acc']:.3f}  var={s['final_var']:.4f}  "
          f"t={s['sim_time']:7.0f}s  {s['total_mb']:7.1f}MB", flush=True)


def _serve_main(argv: List[str]) -> Dict[str, Any]:
    """``repro.api.cli serve --resume-from DIR``: load a spec-hash-verified
    federated checkpoint and serve it under open-loop Poisson load."""
    from repro import serve as serving

    ap = argparse.ArgumentParser(
        prog="repro.api.cli serve",
        description="Serve a federated checkpoint (continuous batching).")
    ap.add_argument("--resume-from", metavar="DIR", required=True,
                    help="checkpoint dir written by --checkpoint-dir; its "
                         "spec.json sidecar names the model + spec hash")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = closed burst")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=0,
                    help="position budget per slot "
                         "(0 = prompt-len + 4*max-new)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", metavar="FILE",
                    help="write the latency/throughput report as JSON")
    args = ap.parse_args(argv)

    try:
        loaded = serving.load_checkpoint(args.resume_from)
        cfg = loaded.config
        max_len = args.max_len or (args.prompt_len + 4 * args.max_new)
        spec = serving.ServeSpec(slots=args.slots, max_len=max_len,
                                 prefill_len=min(args.prompt_len, max_len),
                                 max_new=args.max_new, seed=args.seed)
        reqs = serving.make_requests(args.requests, args.rate,
                                     spec.prefill_len, args.max_new,
                                     cfg.vocab_size, args.seed)
        engine = serving.ServeEngine(cfg, loaded.lm_params, spec)
        done = engine.run(reqs)
    except api.SpecError as e:
        raise SystemExit(f"spec error: {e}")

    rep = serving.report(done)
    rep.update(spec_hash=loaded.spec_hash, step=loaded.step,
               model=loaded.spec.data.model, rate=args.rate,
               traces=dict(engine.trace_counts))
    print(f"serving {rep['model']} @ spec {rep['spec_hash']} "
          f"(step {rep['step']})")
    print(f"  {rep['requests']} requests ({rep['truncated']} truncated)  "
          f"{rep['tok_per_s']:.1f} tok/s  "
          f"p50/p95/p99 latency {rep['latency_p50_s']:.3f}/"
          f"{rep['latency_p95_s']:.3f}/{rep['latency_p99_s']:.3f}s",
          flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rep, f, indent=2)
        print(f"wrote {args.out}", file=sys.stderr)
    return rep


def main(argv: List[str] = None) -> List[api.Result]:
    if argv is None:
        argv = sys.argv[1:]
    if argv and argv[0] == "serve":
        _serve_main(argv[1:])
        return []
    ap = argparse.ArgumentParser(
        prog="repro.api.cli",
        description="Run declarative FL experiments (ExperimentSpec).")
    ap.add_argument("--spec", metavar="FILE",
                    help="JSON ExperimentSpec (default: paper defaults)")
    ap.add_argument("--set", action="append", default=[], dest="sets",
                    metavar="PATH=VALUE",
                    help="override one spec field (repeatable), e.g. "
                         "--set strategy.name=fedat")
    ap.add_argument("--sweep", action="append", default=[], dest="sweeps",
                    metavar="PATH=V1,V2,...",
                    help="add a cartesian grid axis (repeatable), e.g. "
                         "--sweep transport.codec=none,quantize8")
    ap.add_argument("--out", metavar="FILE",
                    help="write per-run results (spec echo + hash + "
                         "trajectory) as JSON")
    ap.add_argument("--checkpoint-dir", metavar="DIR",
                    help="save final params + spec hash after the run "
                         "(single runs only)")
    ap.add_argument("--resume-from", metavar="DIR",
                    help="restore initial params from a --checkpoint-dir "
                         "checkpoint whose spec hash matches")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed run from its newest engine "
                         "snapshot under <checkpoint-dir>/engine (needs "
                         "--checkpoint-dir and faults.checkpoint_every > 0)")
    ap.add_argument("--print-spec", action="store_true",
                    help="print the resolved base spec and exit")
    args = ap.parse_args(argv)
    if (args.checkpoint_dir or args.resume_from) and args.sweeps:
        ap.error("--checkpoint-dir/--resume-from apply to single runs, "
                 "not sweeps")
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume needs --checkpoint-dir (engine snapshots live "
                 "under <checkpoint-dir>/engine)")

    try:
        if args.spec:
            with open(args.spec) as f:
                spec = api.ExperimentSpec.from_dict(json.load(f))
        else:
            spec = api.ExperimentSpec()
        overrides = {}
        for s in args.sets:
            path, val = _parse_assignment(s, "--set")
            overrides[path] = _parse_value(val)
        if overrides:
            spec = spec.with_overrides(overrides)
        if args.print_spec:
            print(spec.to_json())
            return []
        spec.validate()

        grid = {}
        for s in args.sweeps:
            path, vals = _parse_assignment(s, "--sweep")
            grid[path] = [_parse_value(v) for v in vals.split(",")]

        if grid:
            axes = " x ".join(f"{k}[{len(v)}]" for k, v in grid.items())
            print(f"base spec {spec.hash()}  sweep: {axes}", flush=True)
            results = api.sweep(spec, grid, on_result=_print_row)
        else:
            print(f"spec {spec.hash()}", flush=True)
            res = api.build(spec, resume_from=args.resume_from).run(
                checkpoint_dir=args.checkpoint_dir,
                resume_engine=args.resume)
            _print_row(res)
            results = [res]
    except api.SpecError as e:
        raise SystemExit(f"spec error: {e}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"base_spec_hash": spec.hash(),
                       "runs": [_result_record(r) for r in results]},
                      f, indent=2)
        print(f"wrote {args.out}", file=sys.stderr)
    return results


if __name__ == "__main__":
    main()
