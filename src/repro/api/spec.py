"""Declarative experiment specification (the user-facing config surface).

One :class:`ExperimentSpec` composes the whole scenario space the paper
spans — partition skew, tier counts, dropout profiles, codecs, re-tiering,
server policy — from five nested sections:

  * :class:`DataSpec`      what the clients hold (model, partitioner, sizes)
  * :class:`TierSpec`      latency tiers, dropout profile, re-tiering cadence
  * :class:`StrategySpec`  server policy by registry name + kwargs
  * :class:`TransportSpec` the link codec by registry string
  * :class:`EngineSpec`    budget, eval cadence, seed, local-training knobs
  * :class:`MeshSpec`      device mesh for the client-sharded round step
  * :class:`FaultSpec`     deterministic fault plane (churn, blackouts,
    poisoned uplinks, crash-resume cadence)
  * :class:`PopulationSpec` million-client population plane (streaming
    data path, FLGo-style availability/responsiveness/completion
    processes, bundled device-class profiles)
  * :class:`TopologySpec`  hierarchical geo-distributed tree (clients ->
    edge aggregators -> regional silos -> global server) with per-link
    delay bands, per-link codecs, and delayed-gradient compensation

The spec is plain data: ``to_dict``/``from_dict`` round-trip through JSON
(``from_dict`` rejects unknown fields with the valid-field list), and
``hash()`` is a stable content hash over the canonical JSON — stamped into
bench artifacts so every result is attributable to an exact configuration.
``validate()`` front-loads actionable errors (unknown strategy/codec/
partitioner names list what *is* registered) before any expensive build.

Registry extension points: models (``models/registry.register_model``),
strategies (``core/strategies/STRATEGIES``), codecs
(``compress/transport.register_codec``), partitioners
(``data/federated.parse_partitioner`` grammar).  See DESIGN.md §API and
§Model-registry.
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
import json
from typing import Any, Dict, Optional, Tuple

from repro.compress import transport
from repro.core import population as population_mod
from repro.core import topology as topology_mod
from repro.core.simulation import PAPER_DELAY_BANDS, SimConfig

#: Version 7 added the ``topology`` section (hierarchical
#: geo-distributed federation, DESIGN.md §Topology-plane): a declarative
#: clients -> edge aggregators -> regional silos -> global server tree
#: where each link class (``client_edge`` / ``edge_silo`` /
#: ``silo_global``) carries its own deterministic delay band (a
#: dedicated topology rng stream) and its own transport codec, silos
#: enter Eq. 3 asynchronously with the straggler-aware cross weights,
#: and slow silo links can apply delayed-gradient compensation.  The
#: all-defaults section is *exactly* the flat FedAT engine — bitwise
#: identical; the degenerate 1-silo/1-edge zero-delay tree is pinned
#: bitwise against the flat run too.
#: Version 6 added the ``population`` section (million-client population
#: plane, DESIGN.md §Population-plane): an indexed client generator with
#: a streaming/gather data path where only the K sampled clients per
#: round materialize batches, plus FLGo-style stochastic availability /
#: responsiveness / completion processes drawn from dedicated population
#: rng streams.  The all-defaults section is *exactly* the legacy
#: stacked plane — bitwise-identical trajectories.
#: Version 5 added the ``faults`` section (deterministic fault plane:
#: transient client churn, tier blackouts, uplink poisoning + the
#: server-side validation gate, crash-resume checkpoint cadence — all
#: drawn from a dedicated fault rng stream, DESIGN.md §Fault-plane).
#: Version 4 added ``data.attention_backend`` ("auto" | "flash" |
#: "reference"): which attention path transformer-family models run —
#: the kernel layer (Pallas flash / blocked-streaming) or the naive
#: chunked-softmax parity oracle.  Version 3 replaced ``data.task`` (a
#: two-value enum) with ``data.model`` (a registry name:
#: models/registry.py) and added the token-data knobs
#: (``vocab_size``/``seq_len``).  Version 2 added the ``mesh`` section
#: (client-sharded round executor).  Version-1/2/3/4 documents still
#: parse — a ``task`` key migrates through the deprecation shim
#: (``image`` -> ``cnn``, ``text`` -> ``logreg``), missing
#: ``mesh``/``attention_backend``/``faults``/``population``/``topology``
#: get their defaults (a defaulted ``faults`` section is exactly the
#: zero-fault engine; a defaulted ``population`` section is exactly the
#: legacy stacked plane; a defaulted ``topology`` section is exactly the
#: flat FedAT engine) — but serialization always emits the current
#: version, so hashes of re-serialized old specs change (deliberately:
#: the topology scenario is now part of what a result is attributable
#: to).
SPEC_VERSION = 7
_READABLE_VERSIONS = (1, 2, 3, 4, 5, 6, 7)

def _resolve_legacy_task(task: Any, existing_model: Optional[str]) -> str:
    """The ``data.task`` deprecation shim shared by ``from_dict`` and
    ``with_overrides``: map a v1/v2 task value to its registered model
    name (models/registry.LEGACY_TASKS), erroring on unknown values and
    on conflicts with an explicitly given ``data.model``."""
    from repro.models.registry import LEGACY_TASKS
    if task not in LEGACY_TASKS:
        raise SpecError(
            f"data.task (deprecated) must be one of "
            f"{sorted(LEGACY_TASKS)}, got {task!r}; new specs should "
            f"name a registered model via data.model")
    model = LEGACY_TASKS[task]
    if existing_model is not None and existing_model != model:
        raise SpecError(
            f"data.task={task!r} (deprecated) conflicts with "
            f"data.model={existing_model!r}; drop the task key")
    return model


class SpecError(ValueError):
    """A spec failed validation; the message says how to fix it."""


def _strict_fields(cls, d: Dict[str, Any], section: str) -> Dict[str, Any]:
    fields = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - fields)
    if unknown:
        raise SpecError(
            f"unknown field(s) {unknown} in {section} spec; "
            f"valid fields: {sorted(fields)}")
    return d


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise SpecError(msg)


# ---------------------------------------------------------------------------
# sections
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DataSpec:
    """What each client holds and trains.  ``model`` is a registry name
    (models/registry.py); the bound model decides which data kind the
    scenario synthesizes (images, feature vectors, token streams).
    ``seed`` drives the whole environment materialization (partitions,
    latencies, dropout schedule, model init); the engine's event-order
    rng is ``EngineSpec.seed``."""
    #: registered model name: cnn | logreg | tiny_lm | ... (the v1/v2
    #: ``task`` key migrates: image -> cnn, text -> logreg)
    model: str = "cnn"
    n_clients: int = 100
    n_classes: int = 10
    partitioner: str = "#class"          # "#class" | "dirichlet:<alpha>"
    classes_per_client: int = 2          # used by the "#class" partitioner
    samples_per_client: int = 60
    image_hw: int = 12                   # image-kind models
    n_features: int = 128                # features-kind models
    vocab_size: int = 64                 # tokens-kind models
    seq_len: int = 16                    # tokens-kind models
    #: attention path for transformer-family models: "auto" (flash
    #: wherever available — the default) | "flash" (kernel layer) |
    #: "reference" (the chunked-softmax parity oracle).  Non-attention
    #: models ignore it; it still hashes into provenance.
    attention_backend: str = "auto"
    seed: int = 0

    def validate(self) -> None:
        from repro.models import registry as model_registry
        if self.model not in model_registry.MODELS:
            raise SpecError(
                f"unknown model {self.model!r}; "
                f"registered: {model_registry.registered_models()} "
                f"(register new ones via models/registry.register_model)")
        _require(self.vocab_size >= 2 and self.seq_len >= 2,
                 f"data.vocab_size and data.seq_len must be >= 2, got "
                 f"({self.vocab_size}, {self.seq_len})")
        from repro.configs.base import ATTENTION_BACKENDS
        _require(self.attention_backend in ATTENTION_BACKENDS,
                 f"data.attention_backend must be one of "
                 f"{ATTENTION_BACKENDS}, got {self.attention_backend!r}")
        _require(self.n_clients >= 1,
                 f"data.n_clients must be >= 1, got {self.n_clients}")
        _require(self.n_classes >= 2,
                 f"data.n_classes must be >= 2, got {self.n_classes}")
        _require(self.classes_per_client >= 1,
                 f"data.classes_per_client must be >= 1, "
                 f"got {self.classes_per_client}")
        _require(self.samples_per_client >= 1,
                 f"data.samples_per_client must be >= 1, "
                 f"got {self.samples_per_client}")
        from repro.data.federated import parse_partitioner
        try:
            parse_partitioner(self.partitioner)
        except ValueError as e:
            raise SpecError(f"data.partitioner: {e}")


@dataclasses.dataclass
class TierSpec:
    """Latency tiers, the dropout profile, and re-tiering cadence."""
    n_tiers: int = 5
    clients_per_round: int = 10          # sample size per (tier) round
    #: per-band (lo, hi) delay seconds on top of base_compute (paper §6.1)
    delay_bands: Tuple[Tuple[float, float], ...] = PAPER_DELAY_BANDS
    base_compute: float = 1.0
    n_unstable: int = 10                 # permanent dropouts
    dropout_window: Tuple[float, float] = (50.0, 400.0)
    #: rebuild the tier map from drifted latencies every N global updates
    #: (0 = never); wires core/tiering.retier into the engine loop
    retier_every: int = 0
    retier_drift: float = 0.2

    def __post_init__(self):
        self.delay_bands = tuple(
            (float(lo), float(hi)) for lo, hi in self.delay_bands)
        self.dropout_window = tuple(float(v) for v in self.dropout_window)

    def validate(self, n_clients: int) -> None:
        _require(1 <= self.n_tiers <= n_clients,
                 f"tiers.n_tiers must be in [1, n_clients={n_clients}], "
                 f"got {self.n_tiers}")
        _require(self.clients_per_round >= 1,
                 f"tiers.clients_per_round must be >= 1, "
                 f"got {self.clients_per_round}")
        _require(len(self.delay_bands) >= 1,
                 "tiers.delay_bands needs at least one (lo, hi) band")
        for i, (lo, hi) in enumerate(self.delay_bands):
            _require(0 <= lo <= hi,
                     f"tiers.delay_bands[{i}] must satisfy 0 <= lo <= hi, "
                     f"got ({lo}, {hi})")
        _require(0 <= self.n_unstable <= n_clients,
                 f"tiers.n_unstable must be in [0, n_clients={n_clients}], "
                 f"got {self.n_unstable}")
        lo, hi = self.dropout_window
        _require(0 <= lo <= hi,
                 f"tiers.dropout_window must satisfy 0 <= lo <= hi, "
                 f"got ({lo}, {hi})")
        _require(self.retier_every >= 0,
                 f"tiers.retier_every must be >= 0 (0 = never), "
                 f"got {self.retier_every}")
        _require(0 <= self.retier_drift < 1,
                 f"tiers.retier_drift must be in [0, 1), "
                 f"got {self.retier_drift}")


@dataclasses.dataclass
class StrategySpec:
    """Server policy by registry name; kwargs are validated against the
    strategy constructor's signature."""
    name: str = "fedat"
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def validate(self) -> None:
        from repro.core import strategies
        if self.name not in strategies.STRATEGIES:
            raise SpecError(
                f"unknown strategy {self.name!r}; "
                f"registered: {sorted(strategies.STRATEGIES)}")
        if "codec" in self.kwargs:
            raise SpecError(
                "the link codec belongs in transport.codec, not "
                "strategy.kwargs['codec'] (one spec field per dimension)")
        params = inspect.signature(
            strategies.STRATEGIES[self.name]).parameters
        bad = sorted(k for k in self.kwargs if k not in params)
        if bad:
            raise SpecError(
                f"strategy {self.name!r} does not accept kwargs {bad}; "
                f"accepted: {sorted(params)}")


@dataclasses.dataclass
class TransportSpec:
    """The link codec, by registry string (``none``, ``polyline:<p>``,
    ``quantize8``, ``quantize16``, ...).  ``None`` keeps each strategy's
    paper default (FedAT derives polyline from its ``precision`` kwarg;
    the baselines run raw f32 links)."""
    codec: Optional[str] = None

    def validate(self) -> None:
        if self.codec is None:
            return
        try:
            transport.get_codec(self.codec)
        except ValueError as e:
            raise SpecError(f"transport.codec: {e}")


@dataclasses.dataclass
class EngineSpec:
    """Run budget and the local-training execution knobs shared by every
    strategy (they parameterize the client update the environment bakes
    into its fused round step)."""
    total_updates: int = 200
    eval_every: int = 10
    seed: int = 0
    local_epochs: int = 3
    batch_size: int = 10
    lr: float = 1e-3
    prox_lambda: float = 0.4

    def validate(self) -> None:
        _require(self.total_updates >= 1,
                 f"engine.total_updates must be >= 1, "
                 f"got {self.total_updates}")
        _require(self.eval_every >= 1,
                 f"engine.eval_every must be >= 1, got {self.eval_every}")
        _require(self.local_epochs >= 1 and self.batch_size >= 1,
                 "engine.local_epochs and engine.batch_size must be >= 1")


@dataclasses.dataclass
class MeshSpec:
    """Device mesh for the fused round step (DESIGN.md §Scale-mapping).

    ``kind`` selects the mesh family (:mod:`repro.launch.mesh`):

    * ``"single"`` — no mesh; the executor builds the byte-identical
      single-device steps (the default, and the bitwise-parity anchor).
    * ``"host"`` — a mesh over however many devices the host has (force N
      with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before
      jax initializes); ``n_pods > 1`` adds the pod (tier) axis.
    * ``"production"`` — the 256/512-chip datacenter shapes (data axis 16;
      ``n_pods=2`` adds the pod axis).

    With a data axis of size D > 1 the per-round client stack is sharded
    over it, which requires ``tiers.clients_per_round % D == 0`` — checked
    statically here when D is known (``single``/``production``), at
    environment build time for ``host`` (D depends on the runtime device
    count).  ``shard_tiers`` additionally maps the (M, ...) tier-model
    stack onto the pod axis.
    """
    kind: str = "single"                 # single | host | production
    n_pods: int = 1
    shard_tiers: bool = False

    def to_name(self) -> Optional[str]:
        """The :func:`repro.launch.mesh.resolve_mesh` name (None=single)."""
        if self.kind == "single":
            return None
        return self.kind if self.n_pods == 1 else f"{self.kind}:{self.n_pods}"

    @classmethod
    def from_name(cls, name: Optional[str],
                  shard_tiers: bool = False) -> "MeshSpec":
        from repro.launch import mesh as mesh_mod
        kind, n_pods = mesh_mod.parse_mesh_name(name)
        return cls(kind=kind, n_pods=n_pods, shard_tiers=shard_tiers)

    def validate(self, clients_per_round: int,
                 k_field: str = "tiers.clients_per_round") -> None:
        from repro.launch import mesh as mesh_mod
        _require(self.kind in mesh_mod.MESH_KINDS,
                 f"mesh.kind must be one of {mesh_mod.MESH_KINDS}, "
                 f"got {self.kind!r}")
        _require(self.n_pods >= 1,
                 f"mesh.n_pods must be >= 1, got {self.n_pods}")
        if self.kind == "single":
            _require(self.n_pods == 1,
                     "mesh.n_pods > 1 needs mesh.kind 'host' or "
                     "'production' (a single device has no pod axis)")
        if self.kind == "production":
            _require(self.n_pods in (1, 2),
                     f"production mesh has 1 or 2 pods, "
                     f"got mesh.n_pods={self.n_pods}")
        if self.shard_tiers:
            _require(self.n_pods > 1,
                     "mesh.shard_tiers maps tiers onto the pod axis and "
                     "needs mesh.n_pods > 1")
        d = mesh_mod.STATIC_DATA_AXIS.get(self.kind)
        if d and clients_per_round % d:
            k = clients_per_round
            raise SpecError(
                f"{k_field}={k} does not pad to a multiple "
                f"of the {self.kind} mesh data axis (size {d}); use a "
                f"multiple of {d} (e.g. {((k + d - 1) // d) * d}).  For "
                f"'host' meshes this is checked at build time against the "
                f"actual device count.")


@dataclasses.dataclass
class FaultSpec:
    """Deterministic fault plane (DESIGN.md §Fault-plane).

    Every fault draw comes from a dedicated rng stream seeded by
    ``faults.seed`` (core/faults.py), so the all-defaults section is
    *exactly* the zero-fault engine — bitwise identical trajectories,
    pinned by the engine-parity oracle.  Churn shapes the environment's
    availability windows; blackouts/poisoning/clipping act inside the
    engine loop; ``checkpoint_every`` enables bitwise crash-resume.
    """
    #: fraction of clients subject to transient availability churn
    #: (down-windows on top of the permanent-dropout schedule); 0 = off
    churn_rate: float = 0.0
    #: down-windows per churned client
    churn_events: int = 2
    #: mean down-window duration in sim seconds (exponential)
    churn_downtime: float = 30.0
    #: down-window onsets drawn uniformly in this (lo, hi) sim-time window
    churn_window: Tuple[float, float] = (50.0, 400.0)
    #: number of tier blackout events over the run (tiered strategies:
    #: the tier leaves Eq. 3 while dark and bootstraps from the global
    #: model on return; strategies without a tier model ignore them)
    blackouts: int = 0
    #: blackout duration in sim seconds
    blackout_duration: float = 60.0
    #: blackout onsets drawn uniformly in this (lo, hi) sim-time window
    blackout_window: Tuple[float, float] = (50.0, 400.0)
    #: per-round probability that one client's decoded uplink is poisoned
    #: to NaN; any nonzero value (or update_clip) compiles the round-based
    #: strategies' server-side validation gate (core/steps.py)
    nan_rate: float = 0.0
    #: L2 norm the gate clips each client's update delta to (0 = off)
    update_clip: float = 0.0
    #: checkpoint full engine state every N committed updates through
    #: checkpoint/ckpt.py (0 = off); a resumed run replays bitwise
    checkpoint_every: int = 0
    #: the dedicated fault-plane rng stream seed
    seed: int = 0

    def __post_init__(self):
        self.churn_window = tuple(float(v) for v in self.churn_window)
        self.blackout_window = tuple(float(v) for v in self.blackout_window)

    def validate(self) -> None:
        _require(0 <= self.churn_rate <= 1,
                 f"faults.churn_rate must be in [0, 1], "
                 f"got {self.churn_rate}")
        _require(self.churn_events >= 0,
                 f"faults.churn_events must be >= 0, "
                 f"got {self.churn_events}")
        _require(self.churn_downtime > 0,
                 f"faults.churn_downtime must be > 0, "
                 f"got {self.churn_downtime}")
        lo, hi = self.churn_window
        _require(0 <= lo <= hi,
                 f"faults.churn_window must satisfy 0 <= lo <= hi, "
                 f"got ({lo}, {hi})")
        _require(self.blackouts >= 0,
                 f"faults.blackouts must be >= 0, got {self.blackouts}")
        _require(self.blackout_duration > 0,
                 f"faults.blackout_duration must be > 0, "
                 f"got {self.blackout_duration}")
        lo, hi = self.blackout_window
        _require(0 <= lo <= hi,
                 f"faults.blackout_window must satisfy 0 <= lo <= hi, "
                 f"got ({lo}, {hi})")
        _require(0 <= self.nan_rate <= 1,
                 f"faults.nan_rate must be in [0, 1], got {self.nan_rate}")
        _require(self.update_clip >= 0,
                 f"faults.update_clip must be >= 0 (0 = off), "
                 f"got {self.update_clip}")
        _require(self.checkpoint_every >= 0,
                 f"faults.checkpoint_every must be >= 0 (0 = off), "
                 f"got {self.checkpoint_every}")


@dataclasses.dataclass
class PopulationSpec:
    """Million-client population plane (DESIGN.md §Population-plane).

    ``plane`` selects the data path: ``"legacy"`` (the default) keeps the
    seed generator and device-resident stacked train data — with every
    other field at its default this section maps to *no* population
    config at all, so golden trajectories are untouched.  ``"stacked"``
    switches to the indexed population generator (vectorized size/class
    draws, per-client content streams) with the full train stack still
    device-resident; ``"streaming"`` keeps the same generator but
    materializes only the K sampled clients' rows per round, so device
    memory stays flat in N (the 100k–1M regime).

    The stochastic client-state processes follow FLGo's taxonomy and are
    drawn from dedicated population rng streams seeded by ``seed``:

    * ``availability`` — ``"always"``, ``"bernoulli:<p>[:<period>]"``
      (per time-slot of length ``period``, default 20 sim-seconds, each
      client is available with probability p — fresh iid draw per slot),
      or the diurnal ``"sine:<p>,<amp>,<period>"`` (the slot probability
      follows ``clip(p + amp*sin(2*pi*t/period), 0, 1)``).
    * ``responsiveness`` — ``"none"``, ``"lognormal:<sigma>"`` or
      ``"uniform:<lo>,<hi>"``: a per-client latency multiplier applied
      to the profiled latencies *before* tier assignment.
    * ``completion`` — same grammar as availability: per-slot probability
      that a sampled client actually completes its round (incomplete
      clients are dropped before Eq. 4, which renormalizes over the
      survivors without retracing).

    ``profile`` bundles the three processes into device-class presets:
    ``"phone:<frac>"`` marks that fraction of clients as phone-like
    (diurnal sine availability, lognormal responsiveness, bernoulli
    completion — ``core/population.PHONE_PRESET``) with the rest staying
    always-on; the class assignment draws from its own dedicated stream.
    A profile owns the process fields, so combining it with explicit
    non-default availability/responsiveness/completion is rejected.

    ``eval_clients`` caps the server-side eval set to a fixed random
    subset (0 = every client), which keeps the test stack O(1) in N.
    """
    #: "legacy" | "stacked" | "streaming" (see class docstring)
    plane: str = "legacy"
    availability: str = "always"
    responsiveness: str = "none"
    completion: str = "none"
    #: "none" or "phone:<frac>" — bundled device-class preset (owns the
    #: three process fields above)
    profile: str = "none"
    #: eval on a fixed random subset of this many clients (0 = all)
    eval_clients: int = 0
    #: the dedicated population rng stream seed
    seed: int = 0

    def validate(self, n_clients: int) -> None:
        _require(self.plane in population_mod.PLANES,
                 f"population.plane must be one of "
                 f"{population_mod.PLANES}, got {self.plane!r}")
        for field_name, value, off in (
                ("availability", self.availability, "always"),
                ("completion", self.completion, "none")):
            try:
                population_mod.parse_process(value, field_name, off)
            except ValueError as e:
                raise SpecError(f"population.{field_name}: {e}")
        try:
            population_mod.parse_responsiveness(self.responsiveness)
        except ValueError as e:
            raise SpecError(f"population.responsiveness: {e}")
        try:
            prof = population_mod.parse_profile(self.profile)
        except ValueError as e:
            raise SpecError(f"population.profile: {e}")
        if prof is not None and (self.availability != "always"
                                 or self.responsiveness != "none"
                                 or self.completion != "none"):
            raise SpecError(
                f"population.profile={self.profile!r} owns the "
                f"availability/responsiveness/completion processes; drop "
                f"the explicit process fields (or drop the profile)")
        _require(0 <= self.eval_clients <= n_clients,
                 f"population.eval_clients must be in "
                 f"[0, n_clients={n_clients}], got {self.eval_clients}")

    def to_config(self) -> Optional[population_mod.PopulationConfig]:
        """The :class:`SimConfig` payload; ``None`` when every knob is at
        its default (modulo seed), which is *exactly* the legacy plane."""
        cfg = population_mod.PopulationConfig(
            plane=self.plane, availability=self.availability,
            responsiveness=self.responsiveness, completion=self.completion,
            profile=self.profile,
            eval_clients=self.eval_clients, seed=self.seed)
        return cfg if cfg.active else None

    @classmethod
    def from_config(
            cls, pc: Optional[population_mod.PopulationConfig]
    ) -> "PopulationSpec":
        if pc is None:
            return cls()
        return cls(plane=pc.plane, availability=pc.availability,
                   responsiveness=pc.responsiveness,
                   completion=pc.completion, profile=pc.profile,
                   eval_clients=pc.eval_clients, seed=pc.seed)


@dataclasses.dataclass
class TopologySpec:
    """Hierarchical geo-distributed federation (DESIGN.md
    §Topology-plane).

    The tree is clients -> ``edges_per_silo`` edge aggregators per silo
    -> ``n_silos`` regional silos -> the global server.  Silos take
    contiguous client-id blocks (region skew under the ``#class``
    partitioner); edges within a silo are latency tiers.  Edges run the
    synchronous intra-tier Eq. 4 average; each silo enters the global
    Eq. 3 asynchronously with the straggler-aware cross weights (slow
    silos renormalize out during blackouts via the elastic layer).

    Each of the three link classes (``client_edge``, ``edge_silo``,
    ``silo_global``) takes an optional uniform delay band under
    ``delay`` (drawn per scheduled silo round from the dedicated
    topology rng stream, composing with population responsiveness and
    fault churn) and an optional codec override under ``codec``
    (``client_edge`` defaults to the strategy/transport codec,
    the WAN hops default to ``none``); per-link wire bytes are
    accounted separately by the strategy.  ``compensation`` is the
    delayed-gradient strength ``lam``: a silo's update is corrected by
    ``lam * (w_global_now - w_global_at_dispatch)`` before Eq. 3
    ("Stragglers Are Not Disaster", PAPERS.md).

    The all-defaults section maps to *no* topology config (the flat
    FedAT engine, bitwise); the degenerate 1-silo/1-edge zero-delay
    tree is pinned bitwise against the flat ``n_tiers=1`` run.
    """
    n_silos: int = 1
    edges_per_silo: int = 1
    #: clients sampled per edge per round (0 = tiers.clients_per_round)
    clients_per_edge: int = 0
    #: per-link-class [lo, hi] uniform delay bands, e.g.
    #: {"silo_global": [5, 20]}
    delay: Dict[str, Tuple[float, float]] = dataclasses.field(
        default_factory=dict)
    #: per-link-class codec overrides, e.g. {"silo_global": "quantize8"}
    codec: Dict[str, str] = dataclasses.field(default_factory=dict)
    #: delayed-gradient compensation strength lam in [0, 1] (0 = off)
    compensation: float = 0.0
    #: silo s multiplies its silo_global delay by 1 + silo_skew * s
    silo_skew: float = 0.0
    #: the dedicated topology rng stream seed
    seed: int = 0

    def __post_init__(self):
        self.delay = {k: tuple(float(x) for x in v)
                      for k, v in self.delay.items()}
        self.codec = dict(self.codec)

    def validate(self, n_clients: int) -> None:
        _require(self.n_silos >= 1 and self.edges_per_silo >= 1,
                 f"topology.n_silos and topology.edges_per_silo must be "
                 f">= 1, got ({self.n_silos}, {self.edges_per_silo})")
        _require(self.n_silos * self.edges_per_silo <= n_clients,
                 f"topology needs n_silos * edges_per_silo <= "
                 f"n_clients={n_clients}, got "
                 f"{self.n_silos} * {self.edges_per_silo}")
        _require(self.clients_per_edge >= 0,
                 f"topology.clients_per_edge must be >= 0 (0 = inherit "
                 f"tiers.clients_per_round), got {self.clients_per_edge}")
        for field_name, mapping in (("delay", self.delay),
                                    ("codec", self.codec)):
            unknown = sorted(set(mapping) - set(topology_mod.LINK_CLASSES))
            if unknown:
                raise SpecError(
                    f"topology.{field_name} names unknown link class(es) "
                    f"{unknown}; the tree (clients -> edges -> silos -> "
                    f"global) has exactly these link classes: "
                    f"{list(topology_mod.LINK_CLASSES)}")
        for link, band in self.delay.items():
            _require(len(band) == 2 and 0 <= band[0] <= band[1],
                     f"topology.delay[{link!r}] must be [lo, hi] with "
                     f"0 <= lo <= hi, got {list(band)}")
        for link, codec in self.codec.items():
            try:
                transport.get_codec(codec)
            except ValueError as e:
                raise SpecError(f"topology.codec[{link!r}]: {e}")
        _require(0 <= self.compensation <= 1,
                 f"topology.compensation must be in [0, 1], "
                 f"got {self.compensation}")
        _require(self.silo_skew >= 0,
                 f"topology.silo_skew must be >= 0, got {self.silo_skew}")

    def to_config(self) -> Optional[topology_mod.TopologyConfig]:
        """The :class:`SimConfig` payload; ``None`` when every knob is at
        its default (modulo seed), which is *exactly* the flat engine."""
        if (self.n_silos == 1 and self.edges_per_silo == 1
                and self.clients_per_edge == 0 and not self.delay
                and not self.codec and self.compensation == 0
                and self.silo_skew == 0):
            return None
        return topology_mod.TopologyConfig(
            n_silos=self.n_silos, edges_per_silo=self.edges_per_silo,
            clients_per_edge=self.clients_per_edge,
            delay=tuple((k, lo, hi)
                        for k, (lo, hi) in sorted(self.delay.items())),
            codec=tuple(sorted(self.codec.items())),
            compensation=self.compensation, silo_skew=self.silo_skew,
            seed=self.seed)

    @classmethod
    def from_config(
            cls, tc: Optional[topology_mod.TopologyConfig]
    ) -> "TopologySpec":
        if tc is None:
            return cls()
        return cls(n_silos=tc.n_silos, edges_per_silo=tc.edges_per_silo,
                   clients_per_edge=tc.clients_per_edge,
                   delay={k: (lo, hi) for k, lo, hi in tc.delay},
                   codec=dict(tc.codec),
                   compensation=tc.compensation, silo_skew=tc.silo_skew,
                   seed=tc.seed)


# ---------------------------------------------------------------------------
# the composed spec
# ---------------------------------------------------------------------------

_SECTIONS = {"data": DataSpec, "tiers": TierSpec, "strategy": StrategySpec,
             "transport": TransportSpec, "engine": EngineSpec,
             "mesh": MeshSpec, "faults": FaultSpec,
             "population": PopulationSpec, "topology": TopologySpec}


@dataclasses.dataclass
class ExperimentSpec:
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    tiers: TierSpec = dataclasses.field(default_factory=TierSpec)
    strategy: StrategySpec = dataclasses.field(default_factory=StrategySpec)
    transport: TransportSpec = dataclasses.field(
        default_factory=TransportSpec)
    engine: EngineSpec = dataclasses.field(default_factory=EngineSpec)
    mesh: MeshSpec = dataclasses.field(default_factory=MeshSpec)
    faults: FaultSpec = dataclasses.field(default_factory=FaultSpec)
    population: PopulationSpec = dataclasses.field(
        default_factory=PopulationSpec)
    topology: TopologySpec = dataclasses.field(default_factory=TopologySpec)

    # -- validation -----------------------------------------------------
    def validate(self) -> "ExperimentSpec":
        self.data.validate()
        self.tiers.validate(self.data.n_clients)
        self.strategy.validate()
        self.transport.validate()
        self.engine.validate()
        self.mesh.validate(self.tiers.clients_per_round)
        self.faults.validate()
        self.population.validate(self.data.n_clients)
        self.topology.validate(self.data.n_clients)
        if self.topology.to_config() is not None:
            if self.topology.clients_per_edge:
                self.mesh.validate(self.topology.clients_per_edge,
                                   k_field="topology.clients_per_edge")
            _require(self.strategy.name == "fedat",
                     f"the topology plane runs the tiered FedAT strategy "
                     f"(edges = Eq. 4, silos = Eq. 3); got "
                     f"strategy.name={self.strategy.name!r} — drop the "
                     f"topology section or use fedat")
            _require(self.faults.nan_rate == 0
                     and self.faults.update_clip == 0,
                     "the server-side validation gate (faults.nan_rate / "
                     "faults.update_clip) is not supported under the "
                     "topology plane yet; churn, blackouts and "
                     "crash-resume all compose")
        return self

    # -- serialization --------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["tiers"]["delay_bands"] = [list(b)
                                     for b in self.tiers.delay_bands]
        d["tiers"]["dropout_window"] = list(self.tiers.dropout_window)
        d["faults"]["churn_window"] = list(self.faults.churn_window)
        d["faults"]["blackout_window"] = list(self.faults.blackout_window)
        d["topology"]["delay"] = {k: list(v) for k, v
                                  in self.topology.delay.items()}
        d["spec_version"] = SPEC_VERSION
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        version = d.pop("spec_version", SPEC_VERSION)
        if version not in _READABLE_VERSIONS:
            raise SpecError(f"spec_version {version} not supported "
                            f"(this build reads {_READABLE_VERSIONS} and "
                            f"writes {SPEC_VERSION})")
        unknown = sorted(set(d) - set(_SECTIONS))
        if unknown:
            raise SpecError(f"unknown section(s) {unknown} in experiment "
                            f"spec; valid sections: {sorted(_SECTIONS)}")
        parts = {}
        for name, section_cls in _SECTIONS.items():
            sub = d.get(name, {})
            if not isinstance(sub, dict):
                raise SpecError(f"section {name!r} must be an object, "
                                f"got {type(sub).__name__}")
            if name == "data":
                sub = cls._migrate_task(dict(sub))
            parts[name] = section_cls(
                **_strict_fields(section_cls, sub, name))
        return cls(**parts)

    @staticmethod
    def _migrate_task(data: Dict[str, Any]) -> Dict[str, Any]:
        """Deprecation shim: the v1/v2 ``data.task`` enum migrates to the
        registry-backed ``data.model`` (image -> cnn, text -> logreg), so
        old documents — and ``--set data.task=...`` invocations — keep
        producing bitwise-identical runs."""
        if "task" not in data:
            return data
        task = data.pop("task")
        data["model"] = _resolve_legacy_task(task, data.get("model"))
        return data

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    # -- provenance -----------------------------------------------------
    def canonical_json(self) -> str:
        """Key-sorted, whitespace-free JSON: the hash input."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def hash(self) -> str:
        """Stable 12-hex content hash for bench/result provenance."""
        return hashlib.sha256(
            self.canonical_json().encode()).hexdigest()[:12]

    def env_dict(self) -> Dict[str, Any]:
        """The sub-dict that determines :class:`SimEnv` materialization
        (used as the environment cache key): data + tiers minus the
        engine-owned re-tiering cadence, the local-training knobs, and
        the fault plane's *churn* knobs (availability windows live on the
        environment; the engine-plane fault knobs don't re-materialize
        it)."""
        d = self.to_dict()
        tiers = d["tiers"]
        tiers.pop("retier_every"), tiers.pop("retier_drift")
        eng = d["engine"]
        local = {k: eng[k] for k in ("local_epochs", "batch_size", "lr",
                                     "prox_lambda")}
        f = d["faults"]
        churn = {k: f[k] for k in ("churn_rate", "churn_events",
                                   "churn_downtime", "churn_window",
                                   "seed")}
        return {"data": d["data"], "tiers": tiers, "local": local,
                "mesh": d["mesh"], "churn": churn,
                "population": d["population"],
                "topology": d["topology"]}

    def env_hash(self) -> str:
        return hashlib.sha256(json.dumps(
            self.env_dict(), sort_keys=True,
            separators=(",", ":")).encode()).hexdigest()[:12]

    # -- overrides ------------------------------------------------------
    def with_overrides(self, overrides: Dict[str, Any]) -> "ExperimentSpec":
        """A new spec with dotted-path fields replaced, e.g.
        ``{"strategy.name": "fedavg", "transport.codec": "quantize8",
        "strategy.kwargs.use_prox": False}``.  Unknown paths raise
        :class:`SpecError`; new keys may only be created under
        ``strategy.kwargs`` (an open dict by design)."""
        overrides = dict(overrides)
        if "data.task" in overrides:
            # deprecated alias: translate up front (order-independent) so
            # an explicit data.model override conflicts loudly instead of
            # being silently replaced
            overrides["data.model"] = _resolve_legacy_task(
                overrides.pop("data.task"), overrides.get("data.model"))
        d = self.to_dict()
        for path, value in overrides.items():
            parts = path.split(".")
            cur: Any = d
            for i, p in enumerate(parts[:-1]):
                if not isinstance(cur, dict) or p not in cur:
                    raise SpecError(
                        f"unknown spec path {path!r}: no section "
                        f"{'.'.join(parts[:i + 1])!r}; top-level sections: "
                        f"{sorted(_SECTIONS)}")
                cur = cur[p]
            leaf = parts[-1]
            # open dicts: strategy.kwargs by design, and the per-link
            # topology.delay / topology.codec maps (keys are validated
            # against LINK_CLASSES in TopologySpec.validate)
            open_dict = len(parts) >= 2 and (
                parts[-2] == "kwargs"
                or (parts[0] == "topology"
                    and parts[-2] in ("delay", "codec")))
            if not isinstance(cur, dict) or (leaf not in cur
                                             and not open_dict):
                raise SpecError(
                    f"unknown spec field {path!r}; valid fields under "
                    f"{'.'.join(parts[:-1]) or 'the spec root'}: "
                    f"{sorted(cur) if isinstance(cur, dict) else '<leaf>'}")
            cur[leaf] = value
        return ExperimentSpec.from_dict(d)

    # -- bridges to the core layer --------------------------------------
    def to_sim_config(self) -> SimConfig:
        """Materialization recipe for :class:`~repro.core.simulation.
        SimEnv` (the engine-owned knobs stay out: see env_dict)."""
        return SimConfig(
            model=self.data.model, n_clients=self.data.n_clients,
            n_classes=self.data.n_classes,
            classes_per_client=self.data.classes_per_client,
            samples_per_client=self.data.samples_per_client,
            image_hw=self.data.image_hw, n_features=self.data.n_features,
            vocab_size=self.data.vocab_size, seq_len=self.data.seq_len,
            attention_backend=self.data.attention_backend,
            n_tiers=self.tiers.n_tiers,
            clients_per_round=self.tiers.clients_per_round,
            local_epochs=self.engine.local_epochs,
            batch_size=self.engine.batch_size, lr=self.engine.lr,
            prox_lambda=self.engine.prox_lambda,
            n_unstable=self.tiers.n_unstable,
            base_compute=self.tiers.base_compute, seed=self.data.seed,
            partitioner=self.data.partitioner,
            delay_bands=self.tiers.delay_bands,
            dropout_window=self.tiers.dropout_window,
            mesh=self.mesh.to_name(), shard_tiers=self.mesh.shard_tiers,
            churn_rate=self.faults.churn_rate,
            churn_events=self.faults.churn_events,
            churn_downtime=self.faults.churn_downtime,
            churn_window=self.faults.churn_window,
            fault_seed=self.faults.seed,
            population=self.population.to_config(),
            topology=self.topology.to_config())

    @classmethod
    def from_sim_config(cls, sc: SimConfig) -> "ExperimentSpec":
        """The inverse bridge: a truthful spec echo for runs driven through
        an already-built environment (the legacy ``run_*`` shims)."""
        return cls(
            data=DataSpec(
                model=sc.model, n_clients=sc.n_clients,
                n_classes=sc.n_classes, partitioner=sc.partitioner,
                classes_per_client=sc.classes_per_client,
                samples_per_client=sc.samples_per_client,
                image_hw=sc.image_hw, n_features=sc.n_features,
                vocab_size=sc.vocab_size, seq_len=sc.seq_len,
                attention_backend=sc.attention_backend,
                seed=sc.seed),
            tiers=TierSpec(
                n_tiers=sc.n_tiers, clients_per_round=sc.clients_per_round,
                delay_bands=sc.delay_bands, base_compute=sc.base_compute,
                n_unstable=sc.n_unstable,
                dropout_window=sc.dropout_window),
            engine=EngineSpec(
                local_epochs=sc.local_epochs, batch_size=sc.batch_size,
                lr=sc.lr, prox_lambda=sc.prox_lambda),
            mesh=MeshSpec.from_name(sc.mesh, shard_tiers=sc.shard_tiers),
            faults=FaultSpec(
                churn_rate=sc.churn_rate, churn_events=sc.churn_events,
                churn_downtime=sc.churn_downtime,
                churn_window=sc.churn_window, seed=sc.fault_seed),
            population=PopulationSpec.from_config(sc.population),
            topology=TopologySpec.from_config(sc.topology))
