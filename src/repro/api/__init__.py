"""Declarative experiment API (DESIGN.md §API).

    from repro import api

    spec = api.ExperimentSpec(
        data=api.DataSpec(n_clients=40, partitioner="dirichlet:0.3"),
        strategy=api.StrategySpec("fedat", {"use_prox": True}),
        transport=api.TransportSpec(codec="quantize8"),
        engine=api.EngineSpec(total_updates=120),
        mesh=api.MeshSpec(kind="host"))   # client-shard over local devices
    result = api.build(spec).run()

    api.sweep(spec, {"strategy.name": ["fedat", "fedavg"],
                     "transport.codec": ["none", "quantize8"]})

CLI: ``python -m repro.api.cli --spec exp.json --set strategy.name=fedat
--sweep transport.codec=none,quantize8``.
"""
from repro.api.build import (Result, Run, build, clear_env_cache,  # noqa: F401
                             get_env, run_spec, save_checkpoint, sweep)
from repro.api.spec import (SPEC_VERSION, DataSpec, EngineSpec,  # noqa: F401
                            ExperimentSpec, FaultSpec, MeshSpec,
                            PopulationSpec, SpecError, StrategySpec,
                            TierSpec, TopologySpec, TransportSpec)
