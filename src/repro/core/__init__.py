"""FedAT core: tiering, cross-tier weighted aggregation, async scheduler,
the unified event-driven engine (engine.py) with pluggable server
strategies (strategies/) covering the FedAT protocol and the paper's
baselines (FedAvg/TiFL/FedAsync).  The datacenter-scale integration
(pods-as-tiers) lives in core/steps.py + runtime/."""
from repro.core.aggregation import (  # noqa: F401
    cross_tier_weights, global_model, intra_tier_average, uniform_weights,
    weighted_average)
from repro.core.engine import (  # noqa: F401
    EngineConfig, Outcome, ServerStrategy, run_engine, run_strategy)
from repro.core.tiering import TierMap, assign_tiers  # noqa: F401
from repro.core import theory  # noqa: F401  (Theorems 5.1/5.2, executable)
