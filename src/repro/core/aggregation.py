"""Cross-tier weighted aggregation (FedAT Eq. 3 / Algorithm 1).

With per-tier update counts T_1..T_M (total T), tier m gets weight

    w_m = T_{M+1-m} / T

i.e. the *slowest* tier inherits the *fastest* tier's update count: tiers
that update rarely are up-weighted exactly by how often the mirror-image
fast tier updated, so the global model does not drift toward fast tiers.
Weights sum to 1 by construction.  Until the first update (T == 0) the
initial model is returned unchanged (Algorithm 1's t == 0 branch).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def cross_tier_weights(update_counts: jax.Array) -> jax.Array:
    """update_counts: (M,) int -> (M,) weights, reversed-count normalized."""
    counts = jnp.asarray(update_counts, jnp.float32)
    total = jnp.sum(counts)
    rev = counts[::-1]
    uniform = jnp.full_like(rev, 1.0 / rev.shape[0])
    return jnp.where(total > 0, rev / jnp.maximum(total, 1.0), uniform)


def uniform_weights(n_tiers: int) -> jax.Array:
    return jnp.full((n_tiers,), 1.0 / n_tiers, jnp.float32)


def weighted_average(stacked_models: Any, weights: jax.Array) -> Any:
    """stacked_models: pytree with leading dim M -> weighted mean pytree."""
    def avg(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
        return jnp.sum(leaf.astype(jnp.float32) * w, axis=0).astype(leaf.dtype)
    return jax.tree.map(avg, stacked_models)


def intra_tier_average(client_models: Any, n_samples: jax.Array) -> Any:
    """FedAvg within a tier (Eq. 4): weight client k by n_k / N_c.

    client_models: pytree with leading dim = #selected clients.
    """
    w = n_samples.astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1.0)
    return weighted_average(client_models, w)


def global_model(tier_models: Any, update_counts) -> Any:
    """WeightedAverage() from Algorithm 1."""
    return weighted_average(tier_models, cross_tier_weights(update_counts))
