"""Cross-tier weighted aggregation (FedAT Eq. 3 / Algorithm 1).

With per-tier update counts T_1..T_M (total T), tier m gets weight

    w_m = T_{M+1-m} / T

i.e. the *slowest* tier inherits the *fastest* tier's update count: tiers
that update rarely are up-weighted exactly by how often the mirror-image
fast tier updated, so the global model does not drift toward fast tiers.
Weights sum to 1 by construction.  Until the first update (T == 0) the
initial model is returned unchanged (Algorithm 1's t == 0 branch).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def cross_tier_weights(update_counts: jax.Array) -> jax.Array:
    """update_counts: (M,) int -> (M,) weights, reversed-count normalized."""
    counts = jnp.asarray(update_counts, jnp.float32)
    total = jnp.sum(counts)
    rev = counts[::-1]
    uniform = jnp.full_like(rev, 1.0 / rev.shape[0])
    return jnp.where(total > 0, rev / jnp.maximum(total, 1.0), uniform)


def uniform_weights(n_tiers: int) -> jax.Array:
    return jnp.full((n_tiers,), 1.0 / n_tiers, jnp.float32)


# ---------------------------------------------------------------------------
# numpy twins for the per-event hot path (core/executor.py / strategies).
#
# The engine computes these tiny weight vectors once per popped event;
# doing it with eager jnp ops costs a handful of XLA dispatches per event,
# which is real money at 5+ events/sec.  The numpy versions are
# *bitwise-identical* to the jnp versions above: the inputs are exact
# small integers (update counts, sample counts), so the f32 sums are
# exact regardless of accumulation order, and IEEE-754 division is
# correctly rounded in both numpy and XLA.
# ---------------------------------------------------------------------------

def cross_tier_weights_host(update_counts) -> np.ndarray:
    """Numpy twin of :func:`cross_tier_weights` (Eq. 3 weights)."""
    counts = np.asarray(update_counts, np.float32)
    rev = counts[::-1]
    total = counts.sum(dtype=np.float32)
    if total > 0:
        return rev / np.maximum(total, np.float32(1.0))
    return np.full_like(rev, 1.0 / rev.shape[0])


def uniform_weights_host(n_tiers: int) -> np.ndarray:
    """Numpy twin of :func:`uniform_weights`."""
    return np.full((n_tiers,), 1.0 / n_tiers, np.float32)


def client_weights_host(n_samples) -> np.ndarray:
    """Numpy twin of :func:`client_weights` (Eq. 4 weights)."""
    w = np.asarray(n_samples, np.float32)
    return w / np.maximum(w.sum(dtype=np.float32), np.float32(1.0))


def weighted_average(stacked_models: Any, weights: jax.Array) -> Any:
    """stacked_models: pytree with leading dim M -> weighted mean pytree.

    The product is pinned behind an optimization barrier so the weighted
    sum rounds identically whether this runs op-by-op or inside the fused
    round step (core/executor.py): XLA otherwise contracts the multiply
    into the reduction (FMA) in fused programs, which changes the f32
    rounding versus eager dispatch and breaks bitwise trajectory parity.
    """
    def avg(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
        prod = jax.lax.optimization_barrier(leaf.astype(jnp.float32) * w)
        return jnp.sum(prod, axis=0).astype(leaf.dtype)
    return jax.tree.map(avg, stacked_models)


def client_weights(n_samples: jax.Array) -> jax.Array:
    """Eq. 4 normalized client weights: n_k / N_c (zero-count slots get
    exactly 0).

    The fused round step (core/executor.py) evaluates this *eagerly* per
    event and passes the result in as data: the normalizing division must
    run op-by-op, because XLA rewrites division inside fused programs
    (reciprocal-multiply) and that breaks bitwise trajectory parity with
    the eager seed loops.
    """
    w = jnp.asarray(n_samples).astype(jnp.float32)
    return w / jnp.maximum(jnp.sum(w), 1.0)


def intra_tier_average(client_models: Any, n_samples: jax.Array) -> Any:
    """FedAvg within a tier (Eq. 4): weight client k by n_k / N_c.

    client_models: pytree with leading dim = #selected clients.

    Fixed-shape padding contract (core/executor.py): slots with
    ``n_samples == 0`` contribute exactly-zero terms to both the weight
    normalizer and the weighted sum, so padding a shrunken sample to a
    fixed fan-out with zero-count slots is bitwise-neutral.
    """
    return weighted_average(client_models, client_weights(n_samples))


def global_model(tier_models: Any, update_counts) -> Any:
    """WeightedAverage() from Algorithm 1."""
    return weighted_average(tier_models, cross_tier_weights(update_counts))
