"""Jitted train/serve steps, single-pod and multi-pod (FedAT pods-as-tiers).

Datacenter-scale mapping of the paper (DESIGN.md §Scale-mapping):

  * a *tier* is a pod (the ``pod`` mesh axis);
  * intra-tier synchronous training  = sync data-parallel step inside the
    pod (GSPMD all-reduce over ``data``; TP collectives over ``model``);
  * cross-tier asynchronous updates  = per-pod model replicas (params carry
    a leading pod-stacked dim, sharded over ``pod`` via shard_map with the
    ``pod`` axis manual and data/model auto) mixed every ``sync_every``
    steps by Eq. 3 weights computed from per-tier update counts;
  * polyline compression            = blockwise int8/int16 quantization of
    the cross-pod all-gather payload (compress/quantize.py), cutting the
    pod-axis collective bytes ~4x/2x vs f32.

True asynchrony across pods cannot live inside one SPMD program: each pod
runs this step at its own cadence in deployment (launch/train.py drives
that), while the *compiled artifact* proves the cross-pod collective and
sharding are coherent — which is exactly what the multi-pod dry-run grades.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compress import quantize
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import aggregation
from repro.models import common, lm
from repro.optim import adamw, cosine_schedule, global_norm
from repro.runtime import sharding as shd


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def opt_axes_like(param_axes):
    """AdamW m/v shard exactly like their params (ZeRO: fsdp dims sharded)."""
    return {"m": param_axes, "v": param_axes, "count": ()}


@dataclasses.dataclass(frozen=True)
class StepFns:
    train_step: Callable
    init_state: Callable
    state_shardings: Any
    batch_shardings: Any


def _loss_and_grads(cfg, params, batch, tp, microbatch):
    loss_fn = lambda p, b: lm.loss_fn(cfg, p, b, tp)
    if microbatch and microbatch > 1:
        k = microbatch

        def split(x):
            return x.reshape(k, x.shape[0] // k, *x.shape[1:])
        mb = jax.tree.map(split, batch)

        def acc_body(carry, b):
            gsum, lsum = carry
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
            gsum = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                gsum, g)
            return (gsum, lsum + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(acc_body, (g0, 0.0), mb)
        grads = jax.tree.map(lambda g: g / k, gsum)
        return lsum / k, grads
    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    return loss, grads


# ---------------------------------------------------------------------------
# single-pod sync step (one tier)
# ---------------------------------------------------------------------------

def make_single_pod_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh,
                         param_dtype=jnp.float32):
    tp = mesh.shape.get("model", 1) if mesh else 1
    opt = adamw(tcfg.lr, tcfg.betas[0], tcfg.betas[1], tcfg.eps,
                tcfg.weight_decay, grad_clip=tcfg.grad_clip)
    sched = cosine_schedule(1.0, tcfg.warmup_steps, tcfg.total_steps)

    def init_state(key):
        params = lm.init_params(cfg, key, tp, param_dtype)
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    def train_step(state, batch):
        params = lm.anchor_params(cfg, state["params"], tp)
        loss, grads = _loss_and_grads(cfg, params, batch, tp, cfg.microbatch)
        lr_scale = sched(state["step"])
        new_params, new_opt = opt.step(params, grads, state["opt"], lr_scale)
        metrics = {"loss": loss, "grad_norm": global_norm(grads),
                   "lr_scale": lr_scale}
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    # shardings
    axes = lm.param_axes(cfg, tp)
    is_ax = lambda l: isinstance(l, tuple)
    with shd.use_mesh(mesh):
        p_sh = jax.tree.map(lambda a: shd.logical_sharding(a, mesh), axes,
                            is_leaf=is_ax)
        state_sh = {"params": p_sh, "opt": {"m": p_sh, "v": p_sh,
                                            "count": None}, "step": None}
        b_sh = {k: shd.logical_sharding(a, mesh)
                for k, a in lm.input_axes(cfg, None_shape(cfg)).items()}
    return StepFns(train_step, init_state, state_sh, b_sh)


def None_shape(cfg):  # minimal train-kind shape token for input_axes
    from repro.configs.shapes import ShapeConfig
    return ShapeConfig("train", 1, 1, "train")


# ---------------------------------------------------------------------------
# multi-pod FedAT step (pods as tiers)
# ---------------------------------------------------------------------------

INNER_RULES = {"batch": "data", "cache_batch": "data"}  # pod axis is manual


def make_fedat_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh,
                    param_dtype=jnp.float32):
    """Multi-pod train step: per-pod update + compressed cross-tier mix.

    State leaves carry a leading ``n_pods`` dim sharded over the pod axis;
    the per-pod forward/backward/update is vmapped over that dim (pure-auto
    GSPMD — a manual-pod shard_map trips an XLA partitioner bug on gathers
    from sharded embedding tables).  Batches arrive pre-split
    (n_pods, B/n_pods, ...).
    """
    assert "pod" in mesh.shape, "multi-pod mesh required"
    n_pods = mesh.shape["pod"]
    tp = mesh.shape.get("model", 1)
    opt = adamw(tcfg.lr, tcfg.betas[0], tcfg.betas[1], tcfg.eps,
                tcfg.weight_decay, grad_clip=tcfg.grad_clip)
    sched = cosine_schedule(1.0, tcfg.warmup_steps, tcfg.total_steps)
    bits = tcfg.fedat_compress_bits

    axes = lm.param_axes(cfg, tp)
    is_ax = lambda l: isinstance(l, tuple)

    def _mix_leaf(weights, x, leaf_axes):
        """Eq.3 cross-tier aggregation of one pod-stacked leaf (P, ...).

        The quantized payload keeps the leaf's own data/model sharding and
        is only *pod*-replicated: the constraint becomes an all-gather over
        the pod axis alone (int8/int16 on the wire), and the weighted mix
        runs shard-locally.  Scales are per last-dim row (the in-graph
        variant of the 256-block wire codec in compress/quantize.py).
        """
        inner = tuple(leaf_axes)
        if bits == 4 and x.shape[-1] % 2 == 0:
            # beyond-paper: two int4 nibbles per byte on the wire (7.9x vs
            # f32).  Pack pairs along the last dim, all-gather the packed
            # uint8 tensor over the pod axis only, unpack shard-locally.
            qmax = 7.0
            xf = x.astype(jnp.float32)
            scale = jnp.maximum(
                jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / qmax, 1e-30)
            q = jnp.clip(jnp.round(xf / scale), -qmax, qmax) + 8.0
            pairs = q.reshape(*q.shape[:-1], q.shape[-1] // 2, 2)
            packed = (pairs[..., 0] * 16 + pairs[..., 1]).astype(jnp.uint8)
            # barriers pin the pod all-gather to the packed uint8 tensor —
            # without them XLA hoists the dequant before the reshard and
            # the wire silently reverts to f32 (measured).
            packed = jax.lax.optimization_barrier(packed)
            packed = shd.shard(packed, None, *inner)     # pod-only gather
            packed = jax.lax.optimization_barrier(packed)
            scale = shd.shard(scale, None, *inner[:-1], None)
            hi = (packed // 16).astype(jnp.float32) - 8.0
            lo = (packed % 16).astype(jnp.float32) - 8.0
            q2 = jnp.stack([hi, lo], axis=-1).reshape(*packed.shape[:-1],
                                                      x.shape[-1])
            vals = q2 * scale
        elif bits:
            qmax = float((1 << (min(bits, 16) - 1)) - 1)
            dtype = jnp.int8 if bits <= 8 else jnp.int16
            xf = x.astype(jnp.float32)
            scale = jnp.maximum(
                jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / qmax, 1e-30)
            q = jnp.clip(jnp.round(xf / scale), -qmax, qmax).astype(dtype)
            q = jax.lax.optimization_barrier(q)          # keep int on wire
            q = shd.shard(q, None, *inner)               # pod-only all-gather
            q = jax.lax.optimization_barrier(q)
            scale = shd.shard(scale, None, *inner[:-1], None)
            vals = q.astype(jnp.float32) * scale
        else:
            vals = shd.shard(x.astype(jnp.float32), None, *inner)
        mixed = jnp.einsum("p,p...->...", weights, vals)
        return jnp.broadcast_to(mixed[None], x.shape).astype(x.dtype)

    def train_step(state, batch):
        with shd.use_mesh(mesh, INNER_RULES):
            def one(params, opt_state, step, b):
                loss, grads = _loss_and_grads(cfg, params, b, tp,
                                              cfg.microbatch)
                new_p, new_opt = opt.step(params, grads, opt_state,
                                          sched(step))
                return new_p, new_opt, loss

            new_params, new_opt, loss = jax.vmap(one)(
                state["params"], state["opt"], state["step"], batch)
            counts = state["counts"] + 1.0
            w = aggregation.cross_tier_weights(counts)
            do_sync = (state["step"][0] + 1) % tcfg.fedat_sync_every == 0
            mixed = jax.tree.map(
                functools.partial(_mix_leaf, w), new_params, axes,
                is_leaf=lambda l: isinstance(l, jax.Array))
            new_params = jax.tree.map(
                lambda m, p: jnp.where(do_sync, m, p), mixed, new_params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1, "counts": counts}
        return new_state, {"loss": jnp.mean(loss)}

    def init_state(key):
        params = lm.init_params(cfg, key, tp, param_dtype)
        opt_state = opt.init(params)
        stack = lambda t: jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_pods,) + a.shape), t)
        return {"params": stack(params), "opt": stack(opt_state),
                "step": jnp.zeros((n_pods,), jnp.int32),
                "counts": jnp.zeros((n_pods,), jnp.float32)}

    # shardings: leading pod dim + the param's own logical axes
    def pod_sharding(a):
        inner = shd.logical_sharding(tuple(a), mesh)
        return NamedSharding(mesh, P(*(("pod",) + tuple(inner.spec))))

    with shd.use_mesh(mesh):
        p_sh = jax.tree.map(pod_sharding, axes, is_leaf=is_ax)
        pod_only = NamedSharding(mesh, P("pod"))
        repl = NamedSharding(mesh, P())
        state_sh = {"params": p_sh,
                    "opt": {"m": p_sh, "v": p_sh, "count": pod_only},
                    "step": pod_only, "counts": repl}
        b_sh = jax.tree.map(
            lambda a: NamedSharding(
                mesh, P(*(("pod", "data") + (None,) * (len(a) - 1)))),
            lm.input_axes(cfg, None_shape(cfg)),
            is_leaf=lambda l: isinstance(l, tuple))
    return StepFns(train_step, init_state, state_sh, b_sh)


def split_batch_for_pods(batch, n_pods: int):
    """(B, ...) -> (n_pods, B/n_pods, ...) on every leaf (arrays or
    ShapeDtypeStructs)."""
    def split(x):
        shape = (n_pods, x.shape[0] // n_pods) + tuple(x.shape[1:])
        if isinstance(x, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(shape, x.dtype)
        return x.reshape(shape)
    return jax.tree.map(split, batch)


# ---------------------------------------------------------------------------
# server-side update validation gate (fault plane, DESIGN.md §Fault-plane)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UpdateGate:
    """Validation applied to *decoded* client uplinks before Eq. 4:
    non-finite client updates are zero-weighted (and their payloads
    sanitized to the reference params, since NaN * 0 is still NaN inside
    the weighted average) and, when ``clip_norm > 0``, every surviving
    update's delta from the reference is L2-clipped.  Hashable so the
    executor can key a distinct jitted step per gate config."""
    clip_norm: float = 0.0


def poison_updates(client_params, poison):
    """Overwrite poisoned clients' float leaves with NaN — the fault
    plane's stand-in for a corrupted/malicious uplink.  Applied *after*
    the uplink codec decode (a lossy codec would otherwise scrub the
    injected NaNs before the gate ever sees them).  ``poison`` is a (K,)
    bool mask over the padded client axis."""
    def leaf_fn(leaf):
        if not jnp.issubdtype(leaf.dtype, jnp.inexact):
            return leaf
        mask = poison.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.where(mask, jnp.asarray(jnp.nan, leaf.dtype), leaf)
    return jax.tree.map(leaf_fn, client_params)


def gate_updates(client_params, w_intra, ref, clip_norm):
    """The gate body (traced inside the executor's gated round steps).

    ``client_params`` is the K-stacked decoded uplink tree, ``w_intra``
    the (K,) Eq. 4 sample weights, ``ref`` the params the clients trained
    from.  Returns ``(sanitized_params, gated_weights, any_ok)``:

      * clients with any non-finite float leaf get weight 0 and their
        payload replaced by ``ref`` (sanitize-then-weight — a NaN times a
        zero weight would still sink the sum);
      * surviving weights renormalize to 1 over the finite clients, so
        Eq. 4 stays a convex combination;
      * with ``clip_norm > 0`` each surviving delta from ``ref`` is
        clipped to that L2 norm (flat, over the whole update);
      * ``any_ok`` is False when *no* client survived — callers keep the
        previous model in that case.
    """
    k = w_intra.shape[0]
    ok = jnp.ones((k,), bool)
    for leaf in jax.tree.leaves(client_params):
        if not jnp.issubdtype(leaf.dtype, jnp.inexact):
            continue
        ok = ok & jnp.isfinite(leaf).reshape(k, -1).all(axis=1)

    def expand(mask, leaf):
        return mask.reshape((-1,) + (1,) * (leaf.ndim - 1))

    client_params = jax.tree.map(
        lambda l, r: jnp.where(expand(ok, l), l,
                               jnp.broadcast_to(r[None], l.shape)),
        client_params, ref)

    if clip_norm > 0:
        sq = jnp.zeros((k,), jnp.float32)
        for l, r in zip(jax.tree.leaves(client_params),
                        jax.tree.leaves(ref)):
            d = l.astype(jnp.float32) - r[None].astype(jnp.float32)
            sq = sq + jnp.sum(d.reshape(k, -1) ** 2, axis=1)
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
        client_params = jax.tree.map(
            lambda l, r: (r[None].astype(jnp.float32)
                          + (l.astype(jnp.float32)
                             - r[None].astype(jnp.float32))
                          * expand(scale, l)).astype(l.dtype),
            client_params, ref)

    w = w_intra * ok
    total = jnp.sum(w)
    any_ok = total > 0
    w = jnp.where(any_ok, w / jnp.maximum(total, jnp.float32(1e-30)),
                  jnp.zeros_like(w))
    return client_params, w, any_ok
