"""Fused, fixed-shape, device-resident round execution (DESIGN.md §Perf).

The engine's hot path used to pay three host-side taxes per popped event:
re-uploading the selected clients' data from numpy, retracing the client
update whenever dropout shrank the sample to a new length, and running the
Eq. 4 / Eq. 3 aggregation as a swarm of tiny un-jitted dispatches.  The
:class:`RoundExecutor` removes all three:

* **Resident data plane** — ``SimEnv`` uploads the padded train stacks to
  the device once; per-event client selection is an in-graph ``jnp.take``
  over a fixed-length id vector.  Under the **streaming population
  plane** (DESIGN.md §Population-plane) there is no resident stack: the
  K sampled clients' padded batch is host-materialized per round and
  passed to the same step body as data — a jit argument is
  bitwise-identical input to the in-graph gather of the same rows, so
  the two planes share one step body at a distinct ``("stream",)``
  trace key.
* **Fixed-shape padding contract** — a dropout-shrunken sample of ``n``
  live clients is padded to ``clients_per_round`` slots by repeating a
  live id with a **zero aggregation weight**.  Adding exactly-zero terms
  to the Eq. 4 weighted sum is bitwise-neutral, so the trajectory is
  identical to the variable-shape path while the jitted step compiles
  exactly once per strategy configuration.
* **Fused round step** — downlink codec ``lossy`` → gather → vmapped
  local train → uplink ``lossy`` → Eq. 4 intra-tier average →
  ``tier_models.at[m].set`` → Eq. 3 cross-tier aggregation run as one
  jitted call, with buffer donation for the server-state arguments on
  backends that support it (TPU/GPU; CPU ignores donation).

Bitwise parity with the eager seed loops constrains what may live inside
the fused program: XLA rewrites division into reciprocal-multiply and
contracts multiply-into-reduction (FMA) when it can fuse, and neither
rewrite happens in op-by-op dispatch.  So the tiny aggregation *weight*
vectors (Eq. 4 client weights, Eq. 3 cross-tier weights) are computed
eagerly per event and passed in as data, and
:func:`~repro.core.aggregation.weighted_average` pins its product behind
an optimization barrier; the model-sized math (train, codec, averages,
tier-slot scatter) all stays in-graph.

RNG parity: the seed loops draw ``rng.integers(2**31)`` per event and
``jax.random.split`` to the *live* client count.  ``split(key, K)`` is not
prefix-stable in ``split(key, n)``, so the executor splits host-side to
``n`` and pads the key array to ``K`` rows — padded slots train on garbage
keys but carry zero weight.

Trace accounting: every fused step bumps ``trace_counts[step_key]`` at
trace time (a Python side effect inside the jitted function body), which
is what ``tests/test_round_executor.py`` uses to assert zero shape-driven
retraces across a dropout-laden run.

**Client sharding on a device mesh** (DESIGN.md §Scale-mapping).  When the
environment carries a mesh whose ``data`` axis has size D > 1, the
per-round client stack is split over that axis: the vmapped local train +
pinned uplink ``lossy`` + partial Eq. 4 weighted sum run under
``shard_map`` (each device trains K/D clients), and one ``psum`` over
``data`` completes the tier model.  Everything outside that leg — the
downlink ``lossy`` on the replicated global model, the in-graph gather
over the (client-sharded) resident train stacks, the tier-slot scatter,
and the Eq. 3 cross-tier average — stays in the auto-sharded (GSPMD)
region of the same jitted program.  ``clients_per_round`` must be a
multiple of D (checked at :class:`~repro.core.simulation.SimEnv` build).

Parity contract across the mesh dimension: with D == 1 (no mesh, or a
one-device host mesh) the executor builds the *exact* single-device steps
— same trace keys, bitwise-identical trajectories.  With D > 1 the steps
get distinct trace keys (``(..., "dataD")``) and match the single-device
trajectory within a pinned numerical tolerance only: the psum
re-associates the Eq. 4 sum and XLA schedules the shard-local vmap
differently, and blockwise codecs (``quantize8/16``) group their blocks
shard-locally.  ``tests/test_mesh_executor.py`` pins both sides.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import aggregation
from repro.runtime import sharding as shd


def _donate(argnums: Tuple[int, ...]) -> Tuple[int, ...]:
    """Donate server-state buffers where the backend implements donation
    (in-place updates instead of fresh allocations); CPU would only warn."""
    return argnums if jax.default_backend() != "cpu" else ()


def _pin(tree: Any) -> Any:
    """Materialization point inside a fused step.

    The parity oracle (the eager seed loops) rounds every pipeline stage
    to f32 at an op boundary.  Inside one fused program XLA would fuse
    across those boundaries and reassociate / FMA-contract the arithmetic,
    producing ulp-level differences that chaotic training then amplifies.
    Pinning each stage output with an optimization barrier reproduces the
    eager rounding exactly while keeping everything else fused.
    """
    return jax.tree.map(jax.lax.optimization_barrier, tree)


class RoundExecutor:
    """Owns the device-resident data plane and the per-strategy fused round
    steps.  Strategies parameterize a step (prox on/off, codec, aggregation
    weights); the executor caches one compiled step per configuration.

    One executor is cached per :class:`~repro.core.simulation.SimEnv`
    (``env.executor()``) so repeated engine runs over the same environment
    reuse the compile cache.

    The environment's mesh decides the execution shape: with a ``data``
    axis of size D > 1 the per-round client stack runs client-sharded
    under ``shard_map`` (one compiled step per configuration *and* mesh,
    keyed ``(..., "dataD")``); with D == 1 the byte-identical
    single-device steps are built, so a one-device host mesh reproduces
    the no-mesh trajectory bitwise.
    """

    def __init__(self, env):
        self.env = env
        self.K = int(env.sc.clients_per_round)
        #: device mesh (None = single device) and its data-axis size D;
        #: D > 1 selects the shard_map round steps (distinct trace keys),
        #: D == 1 keeps the single-device steps byte-for-byte.
        self.mesh = getattr(env, "mesh", None)
        self.D = int(getattr(env, "data_axis", 1))
        assert self.K % max(self.D, 1) == 0, "SimEnv validates divisibility"
        #: shard the (M, ...) tier-model stack over the mesh's pod axis
        #: (the TiFL/FedAT tier axis); a no-op without a multi-pod mesh.
        #: sized from this env's own mesh only, never the ambient one.
        self.shard_tiers = bool(getattr(env.sc, "shard_tiers", False)) \
            and self.mesh is not None \
            and self.mesh.shape.get("pod", 1) > 1
        #: streaming population plane (DESIGN.md §Population-plane): no
        #: resident train stacks — the K sampled clients' rows are
        #: host-materialized per round and passed to the fused step as
        #: data.  Streaming steps get a distinct ("stream",) trace-key
        #: tag; the step bodies themselves are shared (``_select``).
        self.streaming = bool(getattr(env, "streaming", False))
        self._tag: Tuple[str, ...] = ("stream",) if self.streaming else ()
        #: topology plane (core/topology.py): per-silo rounds fan out
        #: over E edges x K_edge clients in one fused step; None = flat.
        self.topo = getattr(env, "topology", None)
        if self.topo is not None:
            self.E = int(self.topo.edges_per_silo)
            self.K_edge = int(self.topo.k_edge)
        #: high-water mark of the streamed per-round batch bytes (0 until
        #: a streaming round runs; SimEnv.data_plane_bytes reads it)
        self.stream_bytes = 0
        self._steps: Dict[tuple, Any] = {}
        #: step key -> number of times the step body was traced; a fixed-
        #: shape step traces exactly once per configuration.
        self.trace_counts: Dict[tuple, int] = {}

    # ------------------------------------------------------------------
    # host-side marshalling (tiny per-event vectors; the model-sized
    # tensors never leave the device)
    # ------------------------------------------------------------------
    def _pad_ids(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(ids (n,)) -> (padded ids (K,), padded sample counts (K,)).

        Dead slots repeat a live id (valid gather target, finite params)
        and get sample count 0, which zeroes them out of Eq. 4 exactly.
        """
        n = len(ids)
        pid = np.empty(self.K, np.int32)
        pid[:n] = ids
        pid[n:] = ids[0] if n else 0
        ns = np.zeros(self.K, np.float32)
        ns[:n] = self.env.n_train_all[ids]
        return pid, ns

    def _pad_keys(self, seed: int, n: int) -> jax.Array:
        """Split to the live count (rng parity with the seed loops), then
        pad to K rows; padded rows are zero keys behind zero weights."""
        keys = jax.random.split(jax.random.PRNGKey(seed), n)
        if n == self.K:
            return keys
        pad = jnp.zeros((self.K - n,) + keys.shape[1:], keys.dtype)
        return jnp.concatenate([keys, pad], axis=0)

    def _select(self, data):
        """Client rows for the round: an in-graph gather over the resident
        train stacks when ``data`` is the padded id vector, or the
        streamed batch itself when ``data`` is the materialized dict
        (streaming population plane).  A batch passed as a jit argument
        is bitwise-identical input to the in-graph gather of the same
        rows, so the two planes share one step body
        (tests/test_population.py pins the parity)."""
        if isinstance(data, dict):
            return data
        stacks = self.env.train_dev
        return {k: jnp.take(stacks[k], data, axis=0)
                for k in ("x", "y", "mask")}

    def _round_data(self, pid: np.ndarray):
        """What the fused step selects from: the padded id vector
        (resident planes) or the host-materialized padded batch
        (streaming plane).  Padded dead slots repeat a live id, so the
        streamed batch repeats that client's rows — the same selection
        the resident gather produces, behind a zero Eq. 4 weight."""
        if not self.streaming:
            return pid
        batch = self.env.population.materialize(pid)
        self.stream_bytes = max(self.stream_bytes,
                                sum(a.nbytes for a in batch.values()))
        return {k: jnp.asarray(v) for k, v in batch.items()}

    def _pad_topology(self, ids_edges):
        """Per-edge live id lists -> the flat (E*K_edge,) padded id
        vector plus the eagerly-normalized weight vectors: ``w_intra`` is
        per-edge Eq. 4 normalized (each edge's K_edge slots sum to 1 over
        its live clients; empty edges stay all-zero), ``w_edge`` is the
        Eq. 4-over-edges weights ∝ per-edge live sample mass (renormalized
        over non-empty edges).  Dead slots repeat a live id from any edge
        (valid gather target) behind exactly-zero weights — the same
        bitwise-neutral padding contract as :meth:`_pad_ids`."""
        E, Ke = self.E, self.K_edge
        fallback = next(int(ids[0]) for ids in ids_edges if len(ids))
        pid = np.full(E * Ke, fallback, np.int32)
        ns = np.zeros(E * Ke, np.float32)
        w_intra = np.zeros(E * Ke, np.float32)
        edge_samples = np.zeros(E, np.float32)
        counts = []
        for e, ids in enumerate(ids_edges):
            n = len(ids)
            counts.append(n)
            if n:
                pid[e * Ke:e * Ke + n] = ids
                ns[e * Ke:e * Ke + n] = self.env.n_train_all[ids]
                w_intra[e * Ke:(e + 1) * Ke] = \
                    aggregation.client_weights_host(ns[e * Ke:(e + 1) * Ke])
                edge_samples[e] = ns[e * Ke:(e + 1) * Ke].sum(
                    dtype=np.float32)
        return pid, w_intra, aggregation.client_weights_host(edge_samples), \
            counts

    def _pad_topology_keys(self, seed: int, counts) -> jax.Array:
        """Split to the total live count (one split call, rng parity with
        the flat round), then scatter each edge's keys into the head of
        its K_edge slot block; padded rows are zero keys behind zero
        weights."""
        E, Ke = self.E, self.K_edge
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(seed),
                                           sum(counts)))
        out = np.zeros((E * Ke,) + keys.shape[1:], keys.dtype)
        off = 0
        for e, n in enumerate(counts):
            out[e * Ke:e * Ke + n] = keys[off:off + n]
            off += n
        return jnp.asarray(out)

    # ------------------------------------------------------------------
    # fused steps (one compile per configuration, cached)
    # ------------------------------------------------------------------
    def _bump(self, key: tuple) -> None:
        self.trace_counts[key] = self.trace_counts.get(key, 0) + 1

    @staticmethod
    def _check_in_graph(codec) -> None:
        if codec is not None and not codec.in_graph:
            raise NotImplementedError(
                f"codec {codec.name!r} declares in_graph=False; the fused "
                "round step needs a jit-composable lossy() for both links "
                "(all registered codecs are in-graph — see DESIGN.md §Perf)")

    # -- client-sharded leg (mesh data axis, D > 1) ---------------------
    def _train_psum(self, update, lossy):
        """The shard_map'd leg of a sharded round: vmapped local train over
        the K/D shard-local clients, pinned uplink ``lossy``, partial Eq. 4
        weighted sum (same barrier-on-product rounding as
        :func:`~repro.core.aggregation.weighted_average`), then one
        ``psum`` over ``data`` completes the weighted tier average.

        ``w_intra`` arrives already normalized (host-side, exactly as in
        the single-device step), so the psum of shard-partial sums *is*
        the full weighted average; padded zero-weight slots stay exactly
        neutral on whichever shard they land.
        """
        def body(w_sent, batch, keys, w_intra):
            client_params, _ = update(w_sent, batch, keys)
            client_params = (_pin(lossy(_pin(client_params)))
                             if lossy is not None else _pin(client_params))

            def part(leaf):
                w = w_intra.reshape(
                    (-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
                prod = jax.lax.optimization_barrier(
                    leaf.astype(jnp.float32) * w)
                return jnp.sum(prod, axis=0)

            sums = jax.tree.map(part, client_params)
            return jax.tree.map(lambda x: jax.lax.psum(x, "data"), sums)

        # clients split over "data"; unmentioned mesh axes (model, pod)
        # see replicated inputs, so the P() outputs are replicated too
        # (check_rep can't prove that through the psum, hence False).
        return shard_map(body, self.mesh,
                         in_specs=(P(), P("data"), P("data"), P("data")),
                         out_specs=P(), check_rep=False)

    def _tier_place(self, tier_models):
        """Optionally pin the (M, ...) tier stack to the pod (tier) axis
        (logical axis "tiers" -> physical "pod", runtime/sharding.py)."""
        if not self.shard_tiers:
            return tier_models
        return jax.tree.map(
            lambda leaf: jax.lax.with_sharding_constraint(
                leaf, shd.logical_sharding(
                    ("tiers",) + (None,) * (leaf.ndim - 1), self.mesh)),
            tier_models)

    def _fedat_step_sharded(self, codec, use_prox: bool):
        self._check_in_graph(codec)
        key = ("fedat", codec.name, use_prox, f"data{self.D}") + self._tag
        if key in self._steps:
            return self._steps[key]
        env = self.env
        update = env.update_fn_raw if use_prox else env.update_fn_noprox_raw
        train = self._train_psum(update, codec.lossy)
        lossy = codec.lossy

        def step(w_global, tier_models, m, data, w_intra, w_cross, keys):
            self._bump(key)
            w_sent = _pin(lossy(w_global))
            tier_model = _pin(
                train(w_sent, self._select(data), keys, w_intra))
            tier_models = self._tier_place(jax.tree.map(
                lambda s, nw: s.at[m].set(nw), tier_models, tier_model))
            w_global = aggregation.weighted_average(tier_models, w_cross)
            return w_global, tier_models

        self._steps[key] = jax.jit(step, donate_argnums=_donate((0, 1)))
        return self._steps[key]

    def _fedavg_step_sharded(self, codec=None):
        self._check_in_graph(codec)
        key = (("fedavg",) if codec is None else ("fedavg", codec.name)) \
            + (f"data{self.D}",) + self._tag
        if key in self._steps:
            return self._steps[key]
        update = self.env.update_fn_noprox_raw
        train = self._train_psum(update, None if codec is None
                                 else codec.lossy)

        def step(w, data, w_intra, keys):
            self._bump(key)
            w_in = w if codec is None else _pin(codec.lossy(w))
            return train(w_in, self._select(data), keys, w_intra)

        self._steps[key] = jax.jit(step, donate_argnums=_donate((0,)))
        return self._steps[key]

    # -- single-device steps (and the D == 1 path under any mesh) -------
    def _fedat_step(self, codec, use_prox: bool):
        if self.D > 1:
            return self._fedat_step_sharded(codec, use_prox)
        self._check_in_graph(codec)
        key = ("fedat", codec.name, use_prox) + self._tag
        if key in self._steps:
            return self._steps[key]
        env = self.env
        update = env.update_fn_raw if use_prox else env.update_fn_noprox_raw
        lossy = codec.lossy

        def step(w_global, tier_models, m, data, w_intra, w_cross, keys):
            self._bump(key)
            w_sent = _pin(lossy(w_global))
            client_params, _ = update(w_sent, self._select(data), keys)
            client_params = _pin(lossy(_pin(client_params)))
            tier_model = _pin(
                aggregation.weighted_average(client_params, w_intra))
            tier_models = jax.tree.map(lambda s, nw: s.at[m].set(nw),
                                       tier_models, tier_model)
            w_global = aggregation.weighted_average(tier_models, w_cross)
            return w_global, tier_models

        self._steps[key] = jax.jit(step, donate_argnums=_donate((0, 1)))
        return self._steps[key]

    def _fedat_topology_step(self, codecs, use_prox: bool):
        """One fused hierarchical silo round (DESIGN.md §Topology-plane):
        downlink codec chain (silo_global -> edge_silo -> client_edge) on
        the silo's *dispatch-time* global snapshot → vmapped local train
        over all E x K_edge sampled clients → client_edge uplink lossy →
        per-edge Eq. 4 (static unroll over edges, exactly the flat Eq. 4
        body per edge) → edge_silo lossy → Eq. 4 over edges (weights ∝
        live sample mass, renormalized over non-empty edges) → silo_global
        lossy → optional delayed-gradient compensation
        ``lam * (w_global_now - w_dispatch)`` → silo-slot scatter →
        Eq. 3 over the silo stack.

        With 1 silo / 1 edge, zero-width delay bands and default codecs
        every extra stage is an exact identity (x1.0 singleton averages,
        bitwise-neutral pins), so this step reproduces the flat
        :meth:`_fedat_step` trajectory bitwise — pinned by
        tests/test_topology.py.
        """
        ce, es, sg = codecs
        for c in codecs:
            self._check_in_graph(c)
        lam = float(self.topo.cfg.compensation)
        key = ("fedat_topo", ce.name, es.name, sg.name, use_prox, lam) \
            + self._tag
        if key in self._steps:
            return self._steps[key]
        env = self.env
        update = env.update_fn_raw if use_prox else env.update_fn_noprox_raw
        E, Ke = self.E, self.K_edge
        lam32 = jnp.float32(lam)

        def step(w_global, silo_models, dispatch, s, data, w_intra,
                 w_edge, w_cross, keys):
            self._bump(key)
            # the silo trains from the global model it fetched when this
            # round was dispatched (stale under WAN delay), compressed by
            # the downlink chain global -> silo -> edge -> client
            w_stale = _pin(jax.tree.map(lambda d: d[s], dispatch))
            w_sent = _pin(ce.lossy(_pin(es.lossy(_pin(sg.lossy(w_stale))))))
            client_params, _ = update(w_sent, self._select(data), keys)
            client_params = _pin(ce.lossy(_pin(client_params)))
            # per-edge Eq. 4 over each edge's K_edge slots — a static
            # unroll so each edge runs the exact flat Eq. 4 body
            edge_models = []
            for e in range(E):
                pe = jax.tree.map(lambda l, e=e: l[e * Ke:(e + 1) * Ke],
                                  client_params)
                em = _pin(aggregation.weighted_average(
                    pe, w_intra[e * Ke:(e + 1) * Ke]))
                edge_models.append(_pin(es.lossy(_pin(em))))
            edge_stack = jax.tree.map(lambda *ls: jnp.stack(ls),
                                      *edge_models)
            silo_model = _pin(aggregation.weighted_average(
                edge_stack, w_edge))
            silo_model = _pin(sg.lossy(_pin(silo_model)))
            if lam > 0:
                # delayed-gradient compensation ("Stragglers Are Not
                # Disaster"): restore lam of the global drift the silo
                # missed while its round was in flight; the product is
                # pinned so the add never FMA-contracts
                silo_model = _pin(jax.tree.map(
                    lambda m_, g, st: m_ + jax.lax.optimization_barrier(
                        lam32 * (g - st)),
                    silo_model, w_global, w_stale))
            silo_models = self._tier_place(jax.tree.map(
                lambda st, nw: st.at[s].set(nw), silo_models, silo_model))
            w_new = aggregation.weighted_average(silo_models, w_cross)
            # the silo re-fetches the fresh global for its next round
            dispatch = jax.tree.map(lambda d, g: d.at[s].set(g),
                                    dispatch, w_new)
            return w_new, silo_models, dispatch

        self._steps[key] = jax.jit(step, donate_argnums=_donate((1, 2)))
        return self._steps[key]

    def _fedat_step_gated(self, codec, use_prox: bool, gate):
        """FedAT round step with the fault plane's server-side validation
        gate (core/steps.py) spliced in after the uplink decode: poison
        injection (NaN uplinks) → non-finite zero-weighting + renormalize
        → optional delta-norm clip → Eq. 4 over survivors, with the
        previous tier/global model kept when *no* client survives.  A
        distinct trace key (gate config included) keeps the ungated step
        byte-for-byte the parity-oracle body."""
        if self.D > 1:
            raise NotImplementedError(
                "the update validation gate is single-device only for now "
                f"(mesh data axis D={self.D}); run gated fault scenarios "
                "without a mesh data axis")
        self._check_in_graph(codec)
        key = ("fedat", codec.name, use_prox, "gate", gate.clip_norm) \
            + self._tag
        if key in self._steps:
            return self._steps[key]
        from repro.core import steps as fl_steps
        env = self.env
        update = env.update_fn_raw if use_prox else env.update_fn_noprox_raw
        lossy = codec.lossy
        clip = float(gate.clip_norm)

        def step(w_global, tier_models, m, data, w_intra, w_cross, keys,
                 poison):
            self._bump(key)
            w_sent = _pin(lossy(w_global))
            client_params, _ = update(w_sent, self._select(data), keys)
            client_params = _pin(lossy(_pin(client_params)))
            client_params = fl_steps.poison_updates(client_params, poison)
            client_params, w_ok, any_ok = fl_steps.gate_updates(
                client_params, w_intra, w_sent, clip)
            tier_model = _pin(
                aggregation.weighted_average(client_params, w_ok))
            prev = jax.tree.map(lambda s: s[m], tier_models)
            tier_model = jax.tree.map(
                lambda nw, p: jnp.where(any_ok, nw, p), tier_model, prev)
            tier_models = jax.tree.map(lambda s, nw: s.at[m].set(nw),
                                       tier_models, tier_model)
            w_global = aggregation.weighted_average(tier_models, w_cross)
            return w_global, tier_models

        self._steps[key] = jax.jit(step, donate_argnums=_donate((0, 1)))
        return self._steps[key]

    def _fedavg_step(self, codec=None):
        """``codec=None`` is the paper's raw-f32 baseline link and keeps the
        seed step body (and its trace-count key) byte-for-byte; a codec adds
        the same pinned lossy downlink/uplink stages the FedAT step uses."""
        if self.D > 1:
            return self._fedavg_step_sharded(codec)
        self._check_in_graph(codec)
        key = (("fedavg",) if codec is None
               else ("fedavg", codec.name)) + self._tag
        if key in self._steps:
            return self._steps[key]
        update = self.env.update_fn_noprox_raw

        def step(w, data, w_intra, keys):
            self._bump(key)
            w_in = w if codec is None else _pin(codec.lossy(w))
            client_params, _ = update(w_in, self._select(data), keys)
            if codec is not None:
                client_params = _pin(codec.lossy(_pin(client_params)))
            return aggregation.weighted_average(_pin(client_params), w_intra)

        self._steps[key] = jax.jit(step, donate_argnums=_donate((0,)))
        return self._steps[key]

    def _fedavg_step_gated(self, codec, gate):
        """FedAvg/TiFL round step with the validation gate; the no-survivor
        fallback keeps the server's previous model."""
        if self.D > 1:
            raise NotImplementedError(
                "the update validation gate is single-device only for now "
                f"(mesh data axis D={self.D}); run gated fault scenarios "
                "without a mesh data axis")
        self._check_in_graph(codec)
        key = (("fedavg",) if codec is None else ("fedavg", codec.name)) \
            + ("gate", gate.clip_norm) + self._tag
        if key in self._steps:
            return self._steps[key]
        from repro.core import steps as fl_steps
        update = self.env.update_fn_noprox_raw
        clip = float(gate.clip_norm)

        def step(w, data, w_intra, keys, poison):
            self._bump(key)
            w_in = w if codec is None else _pin(codec.lossy(w))
            client_params, _ = update(w_in, self._select(data), keys)
            if codec is not None:
                client_params = _pin(codec.lossy(_pin(client_params)))
            client_params = _pin(client_params)
            client_params = fl_steps.poison_updates(client_params, poison)
            client_params, w_ok, any_ok = fl_steps.gate_updates(
                client_params, w_intra, w_in, clip)
            new_w = aggregation.weighted_average(client_params, w_ok)
            return jax.tree.map(lambda nw, p: jnp.where(any_ok, nw, p),
                                new_w, w)

        self._steps[key] = jax.jit(step)
        return self._steps[key]

    def _fedasync_step(self, codec=None):
        """FedAsync trains one client per event, so there is no client
        fan-out to shard: this step is identical under any mesh (the model
        math itself still lands in the auto-sharded GSPMD region)."""
        self._check_in_graph(codec)
        key = (("fedasync",) if codec is None
               else ("fedasync", codec.name)) + self._tag
        if key in self._steps:
            return self._steps[key]
        update = self.env.update_fn_noprox_raw

        def step(w, data, c_glob, c_loc, keys):
            self._bump(key)
            w_in = w if codec is None else _pin(codec.lossy(w))
            client_params, _ = update(w_in, self._select(data), keys)
            client_w = _pin(jax.tree.map(lambda a: a[0], client_params))
            if codec is not None:
                client_w = _pin(codec.lossy(client_w))
            # pin both products: the eager oracle materializes them before
            # the add, which XLA would otherwise contract into an FMA.
            # The staleness mix interpolates toward the server's own copy
            # of w (downlink loss only affects what the client trained on).
            return jax.tree.map(
                lambda g, l: (jax.lax.optimization_barrier(c_glob * g)
                              + jax.lax.optimization_barrier(c_loc * l)),
                w, client_w)

        self._steps[key] = jax.jit(step, donate_argnums=_donate((0,)))
        return self._steps[key]

    # ------------------------------------------------------------------
    # public per-event entry points
    # ------------------------------------------------------------------
    def fedat_round(self, w_global, tier_models, m: int, ids: np.ndarray,
                    seed: int, *, codec, use_prox: bool, cross_weights,
                    gate=None, poison=None):
        """One FedAT tier-completion round (Algorithm 1 steps 1-5), fused.

        ``cross_weights`` is the (M,) Eq. 3 weight vector, computed
        *eagerly* by the strategy from its update counts (see
        :func:`~repro.core.aggregation.client_weights` on why weight
        normalization must stay out of the fused program).  Returns
        ``(w_global, tier_models)``.

        Donation contract: the server-state arguments (``w_global``,
        ``tier_models``) may be donated on TPU/GPU — callers must pass
        buffers they own (strategies copy ``env.params0`` at bind time)
        and replace their references with the returned values.  The same
        contract holds for the sharded step: shard_map does not change
        which arguments are donated, only how the client fan-out is laid
        out across the mesh.

        With the fault plane's ``gate`` (an :class:`~repro.core.steps.
        UpdateGate`) a distinct gated step is compiled; ``poison`` is the
        (K,) bool uplink-poison mask over the padded client axis (None =
        no poisoning this round).
        """
        pid, ns = self._pad_ids(ids)
        data = self._round_data(pid)
        keys = self._pad_keys(seed, len(ids))
        if gate is None:
            step = self._fedat_step(codec, use_prox)
            return step(w_global, tier_models, np.int32(m), data,
                        aggregation.client_weights_host(ns), cross_weights,
                        keys)
        step = self._fedat_step_gated(codec, use_prox, gate)
        if poison is None:
            poison = np.zeros(self.K, bool)
        return step(w_global, tier_models, np.int32(m), data,
                    aggregation.client_weights_host(ns), cross_weights,
                    keys, poison)

    def fedat_topology_round(self, w_global, silo_models, dispatch, s: int,
                             ids_edges, seed: int, *, codecs,
                             use_prox: bool, cross_weights):
        """One hierarchical silo round (DESIGN.md §Topology-plane), fused.

        ``ids_edges`` is a length-E sequence of per-edge live client id
        arrays (already availability/completion filtered; at least one
        must be non-empty).  ``codecs`` is the (client_edge, edge_silo,
        silo_global) codec triple; ``cross_weights`` the (S,) Eq. 3
        vector, computed eagerly by the strategy.  Returns ``(w_global,
        silo_models, dispatch)`` — the dispatch stack's silo-s slot is
        refreshed to the new global in-graph (the silo re-fetches on its
        next round; resample/blackout paths refresh it eagerly instead).

        Donation: ``silo_models``/``dispatch`` may be donated (TPU/GPU);
        ``w_global`` is never donated — the compensation term reads it
        next to the dispatch snapshot that may alias it.
        """
        if self.D > 1:
            raise NotImplementedError(
                f"the topology plane is single-data-axis for now (mesh "
                f"data axis D={self.D}); use a D==1 mesh — multi-pod "
                f"host meshes with one device per pod still map silos "
                f"onto the pod axis (mesh.shard_tiers)")
        pid, w_intra, w_edge, counts = self._pad_topology(ids_edges)
        data = self._round_data(pid)
        keys = self._pad_topology_keys(seed, counts)
        step = self._fedat_topology_step(codecs, use_prox)
        return step(w_global, silo_models, dispatch, np.int32(s), data,
                    w_intra, w_edge, cross_weights, keys)

    def fedavg_round(self, w, ids: np.ndarray, seed: int, *, codec=None,
                     gate=None, poison=None):
        """One synchronous FedAvg round over the sampled clients, fused.
        ``codec=None`` = the paper's raw f32 links; a codec compresses both
        links exactly as in the FedAT step.  Client-shards over the mesh
        data axis exactly like :meth:`fedat_round` (TiFL rounds run
        through here too).  ``gate``/``poison`` select the fault plane's
        gated step, as in :meth:`fedat_round`."""
        pid, ns = self._pad_ids(ids)
        data = self._round_data(pid)
        keys = self._pad_keys(seed, len(ids))
        if gate is None:
            step = self._fedavg_step(codec)
            return step(w, data, aggregation.client_weights_host(ns), keys)
        step = self._fedavg_step_gated(codec, gate)
        if poison is None:
            poison = np.zeros(self.K, bool)
        return step(w, data, aggregation.client_weights_host(ns), keys,
                    poison)

    def fedasync_round(self, w, client: int, a_eff: float, seed: int, *,
                       codec=None):
        """One asynchronous client update with staleness mix-in, fused.

        The interpolation coefficients are rounded to f32 host-side so the
        in-graph math matches the seed loop's eager ``(1-a)*g + a*l``.
        """
        step = self._fedasync_step(codec)
        keys = jax.random.split(jax.random.PRNGKey(seed), 1)
        data = self._round_data(np.asarray([client], np.int32))
        return step(w, data, np.float32(1.0 - a_eff), np.float32(a_eff),
                    keys)
