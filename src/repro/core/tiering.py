"""Client tiering module (FedAT §4, same scheme as TiFL).

Profiles per-client response latency (the time to finish one local round)
and partitions clients into M logical tiers: tier_1 fastest ... tier_M
slowest.  The paper splits 100 clients into 5 equal parts by latency; we
implement quantile partitioning with optional periodic re-profiling (clients
whose speed drifts migrate tiers).

Also used at datacenter scale: pods (or DP replica groups) are "clients",
their measured step times are the latency profile, and the tier map feeds
the cross-pod FedAT aggregation (runtime/straggler.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np


@dataclasses.dataclass
class TierMap:
    tier_of: np.ndarray          # (n_clients,) int tier index, 0 = fastest
    members: List[np.ndarray]    # per-tier client id arrays
    latencies: np.ndarray        # profile used to build the map

    @property
    def n_tiers(self) -> int:
        return len(self.members)


def assign_tiers(latencies: Sequence[float], n_tiers: int = 5) -> TierMap:
    """Equal-size partition by sorted response latency (paper §6.1)."""
    lat = np.asarray(latencies, np.float64)
    n = len(lat)
    if n_tiers > n:
        raise ValueError(f"n_tiers={n_tiers} > n_clients={n}")
    order = np.argsort(lat, kind="stable")
    splits = np.array_split(order, n_tiers)
    tier_of = np.zeros(n, np.int32)
    for t, ids in enumerate(splits):
        tier_of[ids] = t
    return TierMap(tier_of=tier_of,
                   members=[np.sort(ids) for ids in splits],
                   latencies=lat)


def profile_latencies(base_compute: Sequence[float],
                      tier_delays: Sequence[tuple],
                      rng: np.random.Generator) -> np.ndarray:
    """The paper's simulation: 5 delay bands (0, 0-5, 6-10, 11-15, 20-30 s)
    randomly assigned on top of base compute time."""
    n = len(base_compute)
    parts = np.array_split(rng.permutation(n), len(tier_delays))
    lat = np.asarray(base_compute, np.float64).copy()
    for band, ids in zip(tier_delays, parts):
        lo, hi = band
        lat[ids] += rng.uniform(lo, hi, size=len(ids))
    return lat


def retier(tm: TierMap, new_latencies: Sequence[float]) -> TierMap:
    """Re-profile: rebuild the map, preserving tier count."""
    return assign_tiers(new_latencies, tm.n_tiers)


def drift_latencies(latencies: Sequence[float], rng: np.random.Generator,
                    drift: float = 0.2) -> np.ndarray:
    """A re-profiling measurement: each client's speed drifts by a uniform
    multiplicative factor in [1-drift, 1+drift] (clients near a tier
    boundary migrate when fed back through :func:`retier`)."""
    lat = np.asarray(latencies, np.float64)
    return lat * (1.0 + rng.uniform(-drift, drift, size=len(lat)))


def sample_round_latency(tm: TierMap, tier: int, client_ids: np.ndarray,
                         rng: np.random.Generator, jitter: float = 0.1
                         ) -> float:
    """A tier's round latency = slowest sampled member (intra-tier sync)."""
    base = tm.latencies[client_ids]
    return float(np.max(base * (1.0 + rng.uniform(0, jitter, len(base)))))
