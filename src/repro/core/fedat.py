"""FedAT: intra-tier synchronous + cross-tier asynchronous training
(Algorithm 1), with weighted aggregation (Eq. 3), proximal local objective
(Eq. 5) and lossy uplink/downlink compression (§4.3).

The server keeps one model per tier plus the per-tier update counts; every
tier-completion event triggers

  1. decompress client payloads (deCom in Figure 1),
  2. intra-tier weighted average (Eq. 4)  -> w_{tier_m},
  3. T_{tier_m} += 1 ; t += 1,
  4. global w = sum_m  T_{tier_(M+1-m)} / T * w_{tier_m}   (Eq. 3),
  5. compress + send w to the next ready tier.

Compression on the learning dynamics is modeled in-graph by the exact lossy
step of the polyline codec (round to 10^-p); wire bytes are accounted with
the measured polyline payload ratio (see compress/polyline.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import polyline
from repro.core import aggregation
from repro.core.scheduler import EventQueue, Metrics
from repro.core.simulation import SimEnv
from repro.core.tiering import sample_round_latency


@dataclasses.dataclass
class FedATConfig:
    total_updates: int = 200       # T: global update budget
    precision: Optional[int] = 4   # polyline precision; None = no compression
    weighted: bool = True          # Eq. 3 on/off (ablation: uniform)
    use_prox: bool = True          # Eq. 5 constraint on/off
    eval_every: int = 10
    seed: int = 0


def fake_polyline(params, precision: Optional[int]):
    """The codec's exact lossy step: round to `precision` decimals."""
    if precision is None:
        return params
    f = 10.0 ** precision
    return jax.tree.map(lambda x: jnp.round(x * f) / f, params)


def measure_ratio(params, precision: Optional[int]) -> float:
    """Wire bytes / raw f32 bytes for the polyline codec."""
    if precision is None:
        return 1.0
    msg = polyline.marshal(params, precision)
    return polyline.payload_bytes(msg) / polyline.raw_bytes(params)


def run_fedat(env: SimEnv, fc: FedATConfig) -> Metrics:
    sc = env.sc
    M = env.tm.n_tiers
    rng = np.random.default_rng(fc.seed + 17)

    tier_models = jax.tree.map(
        lambda l: jnp.stack([l] * M), env.params0)        # (M, ...)
    counts = np.zeros(M, np.int64)
    w_global = env.params0
    update_fn = env.update_fn if fc.use_prox else env.update_fn_noprox

    # measured compression ratio (re-measured at every eval point)
    ratio = measure_ratio(env.params0, fc.precision)

    q = EventQueue()
    metrics = Metrics()
    bytes_up = bytes_down = 0.0
    t_global = 0

    # bootstrap: every tier starts round 0 at its own pace
    for m in range(M):
        ids = env.sample_clients(env.tm.members[m], sc.clients_per_round, rng)
        q.push(sample_round_latency(env.tm, m, ids, rng), (m, ids))

    while t_global < fc.total_updates and len(q):
        now, (m, ids) = q.pop()
        alive = env.alive(now)
        ids = ids[alive[ids]]
        if len(ids) == 0:  # whole sample dropped: reschedule the tier
            ids = env.sample_clients(env.tm.members[m][alive[env.tm.members[m]]],
                                     sc.clients_per_round, rng)
            if len(ids) == 0:
                continue
            q.push(sample_round_latency(env.tm, m, ids, rng), (m, ids))
            continue

        # downlink: server -> selected clients (compressed global model)
        w_sent = fake_polyline(w_global, fc.precision)
        bytes_down += len(ids) * env.model_bytes * ratio

        # local training (vmapped over the tier's selected clients)
        rngs = jax.random.split(jax.random.PRNGKey(rng.integers(2**31)),
                                len(ids))
        client_params, _ = update_fn(w_sent, env.client_batch(ids), rngs)

        # uplink: clients -> server (compressed), then deCom + Eq. 4
        client_params = fake_polyline(client_params, fc.precision)
        bytes_up += len(ids) * env.model_bytes * ratio
        tier_model = aggregation.intra_tier_average(client_params,
                                                    env.n_samples(ids))
        tier_models = jax.tree.map(
            lambda s, nw: s.at[m].set(nw), tier_models, tier_model)
        counts[m] += 1
        t_global += 1

        # Eq. 3 cross-tier weighted aggregation
        if fc.weighted:
            w_global = aggregation.global_model(tier_models,
                                                jnp.asarray(counts))
        else:
            w_global = aggregation.weighted_average(
                tier_models, aggregation.uniform_weights(M))

        # next round for this tier
        nxt = env.sample_clients(
            env.tm.members[m][alive[env.tm.members[m]]],
            sc.clients_per_round, rng)
        if len(nxt):
            q.push(sample_round_latency(env.tm, m, nxt, rng), (m, nxt))

        if t_global % fc.eval_every == 0 or t_global == fc.total_updates:
            acc, var = env.evaluate(w_global)
            ratio = measure_ratio(w_global, fc.precision)
            metrics.record(now, t_global, acc, var, bytes_up, bytes_down)
    return metrics
