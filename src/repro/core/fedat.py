"""FedAT entry point (Algorithm 1): intra-tier synchronous + cross-tier
asynchronous training with weighted aggregation (Eq. 3), proximal local
objective (Eq. 5) and lossy uplink/downlink compression (§4.3).

The event loop lives in :mod:`repro.core.engine`; the FedAT policy lives in
:mod:`repro.core.strategies.fedat`.  This module keeps the stable
``run_fedat(env, FedATConfig)`` surface plus the codec helpers the tests
and benchmarks use.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.compress import transport
from repro.core.engine import EngineConfig, Metrics, run_engine
from repro.core.simulation import SimEnv
from repro.core.strategies.fedat import FedATStrategy


@dataclasses.dataclass
class FedATConfig:
    total_updates: int = 200       # T: global update budget
    precision: Optional[int] = 4   # polyline precision; None = no compression
    weighted: bool = True          # Eq. 3 on/off (ablation: uniform)
    use_prox: bool = True          # Eq. 5 constraint on/off
    eval_every: int = 10
    seed: int = 0
    #: transport codec override ("polyline:<p>", "quantize8", "quantize16",
    #: "none"); None derives it from ``precision``
    codec: Optional[str] = None


def fake_polyline(params, precision: Optional[int]):
    """The codec's exact lossy step: round to `precision` decimals."""
    if precision is None:
        return params
    return transport.PolylineCodec(precision).lossy(params)


def measure_ratio(params, precision: Optional[int]) -> float:
    """Wire bytes / raw f32 bytes for the polyline codec (full model)."""
    if precision is None:
        return 1.0
    return transport.PolylineCodec(precision).measure_ratio(params,
                                                            max_elems=None)


def run_fedat(env: SimEnv, fc: FedATConfig) -> Metrics:
    strategy = FedATStrategy(precision=fc.precision, codec=fc.codec,
                             weighted=fc.weighted, use_prox=fc.use_prox)
    return run_engine(env, strategy,
                      EngineConfig(total_updates=fc.total_updates,
                                   eval_every=fc.eval_every, seed=fc.seed))
