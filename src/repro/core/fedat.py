"""FedAT entry point (Algorithm 1): intra-tier synchronous + cross-tier
asynchronous training with weighted aggregation (Eq. 3), proximal local
objective (Eq. 5) and lossy uplink/downlink compression (§4.3).

The event loop lives in :mod:`repro.core.engine`; the FedAT policy lives in
:mod:`repro.core.strategies.fedat`; the declarative user surface lives in
:mod:`repro.api`.  This module keeps the stable ``run_fedat(env,
FedATConfig)`` shim — a thin :class:`~repro.api.ExperimentSpec` wrapper, so
the bitwise parity oracle (tests/test_engine_parity.py) exercises the same
spec-driven path the api exposes — plus the codec helpers the tests and
benchmarks use, routed through the transport registry.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.compress import transport
from repro.core.engine import EngineConfig, Metrics, run_engine  # noqa: F401
from repro.core.simulation import SimEnv


@dataclasses.dataclass
class FedATConfig:
    total_updates: int = 200       # T: global update budget
    precision: Optional[int] = 4   # polyline precision; None = no compression
    weighted: bool = True          # Eq. 3 on/off (ablation: uniform)
    use_prox: bool = True          # Eq. 5 constraint on/off
    eval_every: int = 10
    seed: int = 0
    #: transport codec override ("polyline:<p>", "quantize8", "quantize16",
    #: "none"); None derives it from ``precision``
    codec: Optional[str] = None


def _polyline_codec(precision: Optional[int]) -> transport.Codec:
    """Resolve the paper's precision knob through the transport registry."""
    return transport.get_codec(
        "none" if precision is None else f"polyline:{precision}")


def fake_polyline(params, precision: Optional[int]):
    """The codec's exact lossy step: round to `precision` decimals."""
    return _polyline_codec(precision).lossy(params)


def measure_ratio(params, precision: Optional[int]) -> float:
    """Wire bytes / raw f32 bytes for the polyline codec, on the same
    size-capped sample the engine's byte accounting uses."""
    return _polyline_codec(precision).measure_ratio(params)


def run_fedat(env: SimEnv, fc: FedATConfig) -> Metrics:
    """Spec shim: the legacy surface over :func:`repro.api.build`."""
    from repro import api
    codec = fc.codec.name if isinstance(fc.codec, transport.Codec) \
        else fc.codec
    spec = api.ExperimentSpec.from_sim_config(env.sc)
    spec.strategy = api.StrategySpec(
        "fedat", {"precision": fc.precision, "weighted": fc.weighted,
                  "use_prox": fc.use_prox})
    spec.transport = api.TransportSpec(codec=codec)
    spec.engine.total_updates = fc.total_updates
    spec.engine.eval_every = fc.eval_every
    spec.engine.seed = fc.seed
    return api.build(spec, env=env).run().metrics
