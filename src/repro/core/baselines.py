"""Baseline FL methods from the paper's evaluation (§6.1):

  * FedAvg   — synchronous; sample K clients globally, wait for the slowest.
  * TiFL     — synchronous tiered; pick one tier per round (uniform random,
               the paper's credit scheme degenerates to this under equal
               credits), FedAvg-style aggregation of that tier into the
               single global model.
  * FedAsync — fully asynchronous; every client updates the server model
               independently with polynomial staleness weighting
               (Xie et al. 2019).

All three share SimEnv (identical data, latencies, dropout schedule) and
run uncompressed f32 links, as in the paper's Table 2.  Each is a strategy
over the shared event loop (core/engine.py + core/strategies/); these
wrappers keep the stable ``run_*(env, BaselineConfig)`` surface as thin
shims over :class:`~repro.api.ExperimentSpec` (the declarative surface in
:mod:`repro.api`), so the parity oracle exercises the spec-driven path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

from repro.core.engine import EngineConfig, Metrics, run_engine  # noqa: F401
from repro.core.simulation import SimEnv


@dataclasses.dataclass
class BaselineConfig:
    total_updates: int = 200
    eval_every: int = 10
    seed: int = 0
    # fedasync
    alpha: float = 0.6
    staleness_exp: float = 0.5


def _run(env: SimEnv, bc: BaselineConfig, name: str,
         kwargs: Dict[str, Any]) -> Metrics:
    from repro import api
    spec = api.ExperimentSpec.from_sim_config(env.sc)
    spec.strategy = api.StrategySpec(name, kwargs)
    spec.engine.total_updates = bc.total_updates
    spec.engine.eval_every = bc.eval_every
    spec.engine.seed = bc.seed
    return api.build(spec, env=env).run().metrics


def run_fedavg(env: SimEnv, bc: BaselineConfig) -> Metrics:
    return _run(env, bc, "fedavg", {})


def run_tifl(env: SimEnv, bc: BaselineConfig) -> Metrics:
    return _run(env, bc, "tifl", {})


def run_fedasync(env: SimEnv, bc: BaselineConfig) -> Metrics:
    return _run(env, bc, "fedasync",
                {"alpha": bc.alpha, "staleness_exp": bc.staleness_exp})
