"""Baseline FL methods from the paper's evaluation (§6.1):

  * FedAvg   — synchronous; sample K clients globally, wait for the slowest.
  * TiFL     — synchronous tiered; pick one tier per round (uniform random,
               the paper's credit scheme degenerates to this under equal
               credits), FedAvg-style aggregation of that tier into the
               single global model.
  * FedAsync — fully asynchronous; every client updates the server model
               independently with polynomial staleness weighting
               (Xie et al. 2019).

All three share SimEnv (identical data, latencies, dropout schedule) and
run uncompressed f32 links, as in the paper's Table 2.  Each is a strategy
over the shared event loop (core/engine.py + core/strategies/); these
wrappers keep the stable ``run_*(env, BaselineConfig)`` surface.
"""
from __future__ import annotations

import dataclasses

from repro.core.engine import EngineConfig, Metrics, run_engine
from repro.core.simulation import SimEnv
from repro.core.strategies.fedasync import FedAsyncStrategy
from repro.core.strategies.fedavg import FedAvgStrategy
from repro.core.strategies.tifl import TiFLStrategy


@dataclasses.dataclass
class BaselineConfig:
    total_updates: int = 200
    eval_every: int = 10
    seed: int = 0
    # fedasync
    alpha: float = 0.6
    staleness_exp: float = 0.5


def _engine_cfg(bc: BaselineConfig) -> EngineConfig:
    return EngineConfig(total_updates=bc.total_updates,
                        eval_every=bc.eval_every, seed=bc.seed)


def run_fedavg(env: SimEnv, bc: BaselineConfig) -> Metrics:
    return run_engine(env, FedAvgStrategy(), _engine_cfg(bc))


def run_tifl(env: SimEnv, bc: BaselineConfig) -> Metrics:
    return run_engine(env, TiFLStrategy(), _engine_cfg(bc))


def run_fedasync(env: SimEnv, bc: BaselineConfig) -> Metrics:
    return run_engine(env, FedAsyncStrategy(alpha=bc.alpha,
                                            staleness_exp=bc.staleness_exp),
                      _engine_cfg(bc))
