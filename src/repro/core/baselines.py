"""Baseline FL methods from the paper's evaluation (§6.1):

  * FedAvg   — synchronous; sample K clients globally, wait for the slowest.
  * TiFL     — synchronous tiered; pick one tier per round (uniform random,
               the paper's credit scheme degenerates to this under equal
               credits), FedAvg-style aggregation of that tier into the
               single global model.
  * FedAsync — fully asynchronous; every client updates the server model
               independently with polynomial staleness weighting
               (Xie et al. 2019).

All three share SimEnv (identical data, latencies, dropout schedule) and
run uncompressed f32 links, as in the paper's Table 2.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.scheduler import EventQueue, Metrics
from repro.core.simulation import SimEnv
from repro.core.tiering import sample_round_latency


@dataclasses.dataclass
class BaselineConfig:
    total_updates: int = 200
    eval_every: int = 10
    seed: int = 0
    # fedasync
    alpha: float = 0.6
    staleness_exp: float = 0.5


def run_fedavg(env: SimEnv, bc: BaselineConfig) -> Metrics:
    sc = env.sc
    rng = np.random.default_rng(bc.seed + 29)
    w = env.params0
    q = EventQueue()
    metrics = Metrics()
    bytes_up = bytes_down = 0.0

    for t in range(1, bc.total_updates + 1):
        alive = env.alive(q.now)
        pool = np.arange(sc.n_clients)[alive]
        ids = env.sample_clients(pool, sc.clients_per_round, rng)
        if len(ids) == 0:
            break
        # synchronous round: the server waits for the slowest client
        q.push(sample_round_latency(env.tm, -1, ids, rng), None)
        q.pop()
        bytes_down += len(ids) * env.model_bytes
        rngs = jax.random.split(jax.random.PRNGKey(rng.integers(2**31)),
                                len(ids))
        client_params, _ = env.update_fn_noprox(w, env.client_batch(ids), rngs)
        bytes_up += len(ids) * env.model_bytes
        w = aggregation.intra_tier_average(client_params, env.n_samples(ids))
        if t % bc.eval_every == 0 or t == bc.total_updates:
            acc, var = env.evaluate(w)
            metrics.record(q.now, t, acc, var, bytes_up, bytes_down)
    return metrics


def run_tifl(env: SimEnv, bc: BaselineConfig) -> Metrics:
    sc = env.sc
    rng = np.random.default_rng(bc.seed + 31)
    w = env.params0
    q = EventQueue()
    metrics = Metrics()
    bytes_up = bytes_down = 0.0

    for t in range(1, bc.total_updates + 1):
        m = int(rng.integers(env.tm.n_tiers))
        alive = env.alive(q.now)
        pool = env.tm.members[m][alive[env.tm.members[m]]]
        ids = env.sample_clients(pool, sc.clients_per_round, rng)
        if len(ids) == 0:
            continue
        q.push(sample_round_latency(env.tm, m, ids, rng), None)
        q.pop()
        bytes_down += len(ids) * env.model_bytes
        rngs = jax.random.split(jax.random.PRNGKey(rng.integers(2**31)),
                                len(ids))
        client_params, _ = env.update_fn_noprox(w, env.client_batch(ids), rngs)
        bytes_up += len(ids) * env.model_bytes
        w = aggregation.intra_tier_average(client_params, env.n_samples(ids))
        if t % bc.eval_every == 0 or t == bc.total_updates:
            acc, var = env.evaluate(w)
            metrics.record(q.now, t, acc, var, bytes_up, bytes_down)
    return metrics


def run_fedasync(env: SimEnv, bc: BaselineConfig) -> Metrics:
    sc = env.sc
    rng = np.random.default_rng(bc.seed + 37)
    w = env.params0
    q = EventQueue()
    metrics = Metrics()
    bytes_up = bytes_down = 0.0
    server_version = 0

    # every alive client trains continuously at its own pace
    for c in range(sc.n_clients):
        q.push(float(env.tm.latencies[c]), (int(c), server_version))

    t = 0
    while t < bc.total_updates and len(q):
        now, (c, start_version) = q.pop()
        if not env.alive(now)[c]:
            continue
        bytes_down += env.model_bytes
        rngs = jax.random.split(jax.random.PRNGKey(rng.integers(2**31)), 1)
        ids = np.asarray([c])
        client_params, _ = env.update_fn_noprox(w, env.client_batch(ids), rngs)
        client_w = jax.tree.map(lambda a: a[0], client_params)
        bytes_up += env.model_bytes
        # polynomial staleness weighting (FedAsync)
        staleness = server_version - start_version
        a_eff = bc.alpha * (1.0 + staleness) ** (-bc.staleness_exp)
        w = jax.tree.map(lambda g, l: (1 - a_eff) * g + a_eff * l, w, client_w)
        server_version += 1
        t += 1
        q.push(float(env.tm.latencies[c]) * (1 + rng.uniform(0, 0.1)),
               (c, server_version))
        if t % bc.eval_every == 0 or t == bc.total_updates:
            acc, var = env.evaluate(w)
            metrics.record(now, t, acc, var, bytes_up, bytes_down)
    return metrics
