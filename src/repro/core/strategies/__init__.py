"""Pluggable server strategies for the event-driven engine (core/engine.py).

Each strategy reimplements one of the paper's methods as policy hooks over
the shared loop; the rng draw order inside each hook reproduces the deleted
per-method loops exactly (tests/test_engine_parity.py)."""
from typing import Callable, Dict

from repro.core.engine import ServerStrategy
from repro.core.strategies.fedat import FedATStrategy  # noqa: F401
from repro.core.strategies.fedavg import FedAvgStrategy  # noqa: F401
from repro.core.strategies.fedasync import FedAsyncStrategy  # noqa: F401
from repro.core.strategies.tifl import TiFLStrategy  # noqa: F401

STRATEGIES: Dict[str, Callable[..., ServerStrategy]] = {
    "fedat": FedATStrategy,
    "fedavg": FedAvgStrategy,
    "tifl": TiFLStrategy,
    "fedasync": FedAsyncStrategy,
}


def make_strategy(name: str, **kwargs) -> ServerStrategy:
    if name not in STRATEGIES:
        raise ValueError(f"unknown strategy {name!r}; "
                         f"registered: {sorted(STRATEGIES)}")
    return STRATEGIES[name](**kwargs)
