"""FedAvg as an engine strategy: synchronous global rounds — sample K
clients globally, wait for the slowest (paper §6.1).

The paper's baseline runs raw f32 links (``codec=None``, the default, which
keeps the seed trajectory bitwise); passing a transport codec compresses
both links exactly like the FedAT step, opening the strategy x codec plane
to the sweep API.

A round is scheduled while handling the previous round's completion event
(sampling against liveness at that simulated instant, like the seed loop's
round head), so the engine's queue always holds exactly one round event.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import transport
from repro.core.engine import (EngineConfig, EngineContext, Outcome,
                               ServerStrategy)
from repro.core.simulation import SimEnv
from repro.core.tiering import sample_round_latency


class FedAvgStrategy(ServerStrategy):
    name = "fedavg"
    seed_offset = 29
    #: an empty draw ends the run (no liveness left to wait for) — TiFL
    #: overrides this to burn the round instead
    reschedule_on_empty = False

    def __init__(self, codec: Union[str, transport.Codec, None] = None,
                 ratio_sample_elems: Optional[int]
                 = transport.RATIO_SAMPLE_ELEMS):
        self.codec = None if codec is None else transport.get_codec(codec)
        self.ratio_sample_elems = ratio_sample_elems

    def bind(self, env: SimEnv, cfg: EngineConfig) -> None:
        # copy: the fused step may donate this buffer (executor contract)
        self.w = jax.tree.map(jnp.array, env.params0)
        self._ratio = (1.0 if self.codec is None else
                       self.codec.measure_ratio(env.params0,
                                                self.ratio_sample_elems))

    def bootstrap(self, env: SimEnv, ctx: EngineContext) -> None:
        self._schedule(env, ctx)

    def _sample(self, env, ctx):
        """(tier index, client ids) for the next round; -1 = global pool."""
        alive = env.alive(ctx.q.now)
        pool = np.arange(env.sc.n_clients)[alive]
        return -1, env.sample_clients(pool, env.sc.clients_per_round, ctx.rng)

    def _schedule(self, env: SimEnv, ctx: EngineContext) -> None:
        m, ids = self._sample(env, ctx)
        if len(ids) == 0:
            if self.reschedule_on_empty:  # zero-latency budget-burn marker
                ctx.q.push(0.0, (m, ids))
            return  # else: queue drains and the run ends (seed's ``break``)
        ctx.q.push(sample_round_latency(env.tm, m, ids, ctx.rng), (m, ids))

    def on_event(self, env: SimEnv, ctx: EngineContext, now: float,
                 actor) -> Outcome:
        m, ids = actor
        if len(ids) == 0:
            self._schedule(env, ctx)
            return Outcome.SKIP_ROUND
        done = env.completion(now)
        if done is not None:
            # population completion process: drop the sampled clients that
            # fail to report back; the sample-weighted average renormalizes
            # over the survivors in the same fused step (no retrace)
            ids = ids[done[ids]]
            if len(ids) == 0:
                self._schedule(env, ctx)
                return Outcome.SKIP_ROUND
        ctx.bytes_down += len(ids) * env.model_bytes * self._ratio
        # fused round: gather resident data -> vmapped local train ->
        # sample-weighted FedAvg, one jitted call (core/executor.py)
        gate = None if ctx.faults is None else ctx.faults.gate
        if gate is None:
            self.w = ctx.executor.fedavg_round(self.w, ids, ctx.draw_seed(),
                                               codec=self.codec)
        else:
            poison = ctx.faults.draw_poison(len(ids), ctx.executor.K)
            self.w = ctx.executor.fedavg_round(self.w, ids, ctx.draw_seed(),
                                               codec=self.codec, gate=gate,
                                               poison=poison)
        ctx.bytes_up += len(ids) * env.model_bytes * self._ratio
        self._schedule(env, ctx)
        return Outcome.STEP

    def global_params(self):
        return self.w

    def on_eval(self, env: SimEnv, ctx: EngineContext) -> None:
        if self.codec is not None:  # track the drifting wire ratio, sampled
            self._ratio = self.codec.measure_ratio(self.w,
                                                   self.ratio_sample_elems)

    # -- crash-resume ---------------------------------------------------
    def snapshot(self):
        return {"w": self.w}, {"ratio": self._ratio}

    def restore(self, dev, host) -> None:
        self.w = dev["w"]
        self._ratio = host["ratio"]
