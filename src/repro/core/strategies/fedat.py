"""FedAT as an engine strategy: intra-tier synchronous rounds + cross-tier
asynchronous aggregation (Algorithm 1) over a codec-compressed link.

Event = (tier m, sampled client ids).  Every tier-completion event triggers

  1. decompress client payloads (deCom in Figure 1) — modeled in-graph by
     the codec's exact lossy step,
  2. intra-tier weighted average (Eq. 4)  -> w_{tier_m},
  3. T_{tier_m} += 1 ; t += 1,
  4. global w = sum_m  T_{tier_(M+1-m)} / T * w_{tier_m}   (Eq. 3),
  5. compress + send w to the next ready tier.

Wire bytes are accounted with the codec's measured payload ratio,
re-measured at every eval point on a size-capped parameter sample (see
compress/transport.py on the accounting approximation).
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import transport
from repro.core import aggregation
from repro.core import faults as faults_mod
from repro.core.engine import (EngineConfig, EngineContext, Outcome,
                               ServerStrategy)
from repro.core.simulation import SimEnv
from repro.core.tiering import sample_round_latency
from repro.runtime import elastic


class FedATStrategy(ServerStrategy):
    name = "fedat"
    seed_offset = 17

    def __init__(self, precision: Optional[int] = 4,
                 codec: Union[str, transport.Codec, None] = None,
                 weighted: bool = True, use_prox: bool = True,
                 ratio_sample_elems: Optional[int]
                 = transport.RATIO_SAMPLE_ELEMS):
        """``codec`` overrides the paper's default link; when None, it is
        derived from ``precision`` (polyline:<p>, or identity links for
        precision=None) to keep the seed configuration surface."""
        if codec is None:
            codec = "none" if precision is None else f"polyline:{precision}"
        self.codec = transport.get_codec(codec)
        self.weighted = weighted
        self.use_prox = use_prox
        self.ratio_sample_elems = ratio_sample_elems

    # ------------------------------------------------------------------
    def bind(self, env: SimEnv, cfg: EngineConfig) -> None:
        M = env.tm.n_tiers
        self.tier_models = jax.tree.map(
            lambda l: jnp.stack([l] * M), env.params0)    # (M, ...)
        # update counts stay host-side (tiny, and the Eq. 3 weights must
        # be computed eagerly — see aggregation.client_weights); model
        # state is device-resident, copied because the fused step may
        # donate these buffers (executor donation contract)
        self.counts = np.zeros(M, np.int64)
        self.w_global = jax.tree.map(jnp.array, env.params0)
        self._ratio = self.codec.measure_ratio(env.params0,
                                               self.ratio_sample_elems)
        #: per-tier availability under the fault plane's blackouts; all-
        #: True keeps the zero-fault Eq. 3 path byte-for-byte (the masked
        #: renormalization only runs while some tier is dark)
        self.tier_alive = np.ones(M, bool)

    def bootstrap(self, env: SimEnv, ctx: EngineContext) -> None:
        # every tier starts round 0 at its own pace
        for m in range(env.tm.n_tiers):
            ids = env.sample_clients(env.tm.members[m],
                                     env.sc.clients_per_round, ctx.rng)
            ctx.q.push(sample_round_latency(env.tm, m, ids, ctx.rng),
                       (m, ids))

    def on_event(self, env: SimEnv, ctx: EngineContext, now: float,
                 actor) -> Outcome:
        m, ids = actor
        if not self.tier_alive[m]:
            # the round completed into a blackout: the in-flight work is
            # lost with the tier (on_fault reseeds it when it returns)
            return Outcome.DISCARD
        alive = env.alive(now)
        ids = ids[alive[ids]]
        done = env.completion(now)
        if done is not None:
            # population completion process: a sampled, still-alive client
            # can fail to return its update this round — Eq. 4 renormalizes
            # over the survivors inside the same fused step (no retrace)
            ids = ids[done[ids]]
        if len(ids) == 0:  # whole sample dropped: reschedule the tier
            pool = env.tm.members[m][alive[env.tm.members[m]]]
            ids = env.sample_clients(pool, env.sc.clients_per_round, ctx.rng)
            if len(ids):
                ctx.q.push(sample_round_latency(env.tm, m, ids, ctx.rng),
                           (m, ids))
            return Outcome.DISCARD

        # one fused device step: codec downlink -> vmapped local train ->
        # codec uplink -> Eq. 4 intra-tier average -> tier slot update ->
        # Eq. 3 cross-tier aggregation (core/executor.py); byte accounting
        # uses the *live* count, padding slots carry zero weight.  Eq. 3
        # weights come from the post-increment counts and are computed
        # eagerly (training never feeds back into them).
        ctx.bytes_down += len(ids) * env.model_bytes * self._ratio
        self.counts[m] += 1
        if not self.tier_alive.all():
            # blackout in progress elsewhere: Eq. 3 renormalizes over the
            # surviving M' tiers (runtime/elastic.py) — dead tiers get
            # weight exactly 0 whether weighted or uniform
            if self.weighted:
                cw = elastic.masked_cross_weights(self.counts,
                                                  self.tier_alive)
            else:
                cw = (self.tier_alive.astype(np.float32)
                      / self.tier_alive.sum())
        elif self.weighted:
            cw = aggregation.cross_tier_weights_host(self.counts)
        else:
            cw = aggregation.uniform_weights_host(len(self.counts))
        gate = None if ctx.faults is None else ctx.faults.gate
        if gate is None:
            self.w_global, self.tier_models = ctx.executor.fedat_round(
                self.w_global, self.tier_models, m, ids, ctx.draw_seed(),
                codec=self.codec, use_prox=self.use_prox, cross_weights=cw)
        else:
            poison = ctx.faults.draw_poison(len(ids), ctx.executor.K)
            self.w_global, self.tier_models = ctx.executor.fedat_round(
                self.w_global, self.tier_models, m, ids, ctx.draw_seed(),
                codec=self.codec, use_prox=self.use_prox, cross_weights=cw,
                gate=gate, poison=poison)
        ctx.bytes_up += len(ids) * env.model_bytes * self._ratio

        # next round for this tier
        nxt = env.sample_clients(
            env.tm.members[m][alive[env.tm.members[m]]],
            env.sc.clients_per_round, ctx.rng)
        if len(nxt):
            ctx.q.push(sample_round_latency(env.tm, m, nxt, ctx.rng),
                       (m, nxt))
        return Outcome.STEP

    def global_params(self):
        return self.w_global

    def on_eval(self, env: SimEnv, ctx: EngineContext) -> None:
        # track the wire ratio as the weight distribution drifts (sampled)
        self._ratio = self.codec.measure_ratio(self.w_global,
                                               self.ratio_sample_elems)

    # -- fault plane ----------------------------------------------------
    def on_fault(self, env: SimEnv, ctx: EngineContext, now: float,
                 actor) -> Outcome:
        """Tier blackout lifecycle.  Start marker: mark the tier dark and
        schedule its return; rounds completing into the blackout are
        discarded (on_event) and Eq. 3 renormalizes over the survivors.
        Return marker: the tier bootstraps from the current global model
        (the 'Eq. 3 is defined for any M' grow move, runtime/elastic.py),
        restarts its update count, and rejoins the event loop."""
        kind = actor[0]
        if kind == faults_mod.BLACKOUT:
            _, m, t_end = actor
            self.tier_alive[m] = False
            ctx.q.push(t_end - now, (faults_mod.RETURN, m))
            return Outcome.DISCARD
        m = actor[1]
        self.tier_alive[m] = True
        self.tier_models = elastic.bootstrap_tier(
            self.tier_models, self.w_global, m)
        self.counts[m] = 0
        alive = env.alive(now)
        ids = env.sample_clients(
            env.tm.members[m][alive[env.tm.members[m]]],
            env.sc.clients_per_round, ctx.rng)
        if len(ids):
            ctx.q.push(sample_round_latency(env.tm, m, ids, ctx.rng),
                       (m, ids))
        return Outcome.DISCARD

    # -- crash-resume ---------------------------------------------------
    def snapshot(self):
        dev = {"w_global": self.w_global, "tier_models": self.tier_models}
        host = {"counts": self.counts.copy(), "ratio": self._ratio,
                "tier_alive": self.tier_alive.copy()}
        return dev, host

    def restore(self, dev, host) -> None:
        self.w_global = dev["w_global"]
        self.tier_models = dev["tier_models"]
        self.counts = np.asarray(host["counts"], np.int64)
        self._ratio = host["ratio"]
        self.tier_alive = np.asarray(host["tier_alive"], bool)
