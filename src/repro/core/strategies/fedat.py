"""FedAT as an engine strategy: intra-tier synchronous rounds + cross-tier
asynchronous aggregation (Algorithm 1) over a codec-compressed link.

Event = (tier m, sampled client ids).  Every tier-completion event triggers

  1. decompress client payloads (deCom in Figure 1) — modeled in-graph by
     the codec's exact lossy step,
  2. intra-tier weighted average (Eq. 4)  -> w_{tier_m},
  3. T_{tier_m} += 1 ; t += 1,
  4. global w = sum_m  T_{tier_(M+1-m)} / T * w_{tier_m}   (Eq. 3),
  5. compress + send w to the next ready tier.

Wire bytes are accounted with the codec's measured payload ratio,
re-measured at every eval point on a size-capped parameter sample (see
compress/transport.py on the accounting approximation).

**Topology mode** (DESIGN.md §Topology-plane).  When the environment
carries a topology (``env.topology``), the hierarchy replaces the flat
tiers: event = (silo s, per-edge sampled client ids).  Each silo round
fans out over its E edges in one fused step — per-edge Eq. 4 at the
edges, Eq. 4 over edges at the silo, then the silo enters the global
Eq. 3 asynchronously with the same straggler-aware cross weights (silo
blackouts renormalize through the elastic layer exactly like tier
blackouts).  Each link class carries its own codec and delay band;
per-link wire bytes land in ``link_bytes`` while the engine Metrics
keep their flat client-link semantics.  A silo trains from the global
model snapshot taken when its round was *dispatched* (the staleness
WAN delay creates), and ``topology.compensation`` repairs that
staleness with the delayed-gradient term before Eq. 3.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import transport
from repro.core import aggregation
from repro.core import faults as faults_mod
from repro.core.engine import (EngineConfig, EngineContext, Outcome,
                               ServerStrategy)
from repro.core import topology as topology_mod
from repro.core.simulation import SimEnv
from repro.core.tiering import sample_round_latency
from repro.runtime import elastic


class FedATStrategy(ServerStrategy):
    name = "fedat"
    seed_offset = 17

    def __init__(self, precision: Optional[int] = 4,
                 codec: Union[str, transport.Codec, None] = None,
                 weighted: bool = True, use_prox: bool = True,
                 ratio_sample_elems: Optional[int]
                 = transport.RATIO_SAMPLE_ELEMS):
        """``codec`` overrides the paper's default link; when None, it is
        derived from ``precision`` (polyline:<p>, or identity links for
        precision=None) to keep the seed configuration surface."""
        if codec is None:
            codec = "none" if precision is None else f"polyline:{precision}"
        self.codec = transport.get_codec(codec)
        self.weighted = weighted
        self.use_prox = use_prox
        self.ratio_sample_elems = ratio_sample_elems

    # ------------------------------------------------------------------
    def bind(self, env: SimEnv, cfg: EngineConfig) -> None:
        self.topo = getattr(env, "topology", None)
        if self.topo is not None:
            self._bind_topology(env)
            return
        M = env.tm.n_tiers
        self.tier_models = jax.tree.map(
            lambda l: jnp.stack([l] * M), env.params0)    # (M, ...)
        # update counts stay host-side (tiny, and the Eq. 3 weights must
        # be computed eagerly — see aggregation.client_weights); model
        # state is device-resident, copied because the fused step may
        # donate these buffers (executor donation contract)
        self.counts = np.zeros(M, np.int64)
        self.w_global = jax.tree.map(jnp.array, env.params0)
        self._ratio = self.codec.measure_ratio(env.params0,
                                               self.ratio_sample_elems)
        #: per-tier availability under the fault plane's blackouts; all-
        #: True keeps the zero-fault Eq. 3 path byte-for-byte (the masked
        #: renormalization only runs while some tier is dark)
        self.tier_alive = np.ones(M, bool)

    def _bind_topology(self, env: SimEnv) -> None:
        """Topology-mode server state: the silo stack plays the tier
        stack's role (``tier_models``/``counts``/``tier_alive`` are
        silo-indexed so the elastic blackout machinery carries over),
        plus the per-silo dispatch-snapshot stack, the per-link codec
        triple with separate wire-ratio/byte ledgers, and the dedicated
        link-delay rng stream (per run, snapshotted for crash-resume)."""
        topo = self.topo
        S = topo.n_silos
        self.tier_models = jax.tree.map(
            lambda l: jnp.stack([l] * S), env.params0)    # silo stack
        # dispatch[s] = the global model silo s last fetched; staleness
        # for the compensation term is measured against this snapshot
        self.dispatch = jax.tree.map(
            lambda l: jnp.stack([l] * S), env.params0)
        self.counts = np.zeros(S, np.int64)
        self.w_global = jax.tree.map(jnp.array, env.params0)
        self.tier_alive = np.ones(S, bool)
        # client_edge inherits the strategy/transport codec (the flat
        # link); the WAN hops default to identity so the degenerate tree
        # stays bitwise — override per link via topology.codec
        self.link_codecs = tuple(
            transport.get_codec(topo.cfg.codec_name(link, default))
            for link, default in (("client_edge", self.codec.name),
                                  ("edge_silo", "none"),
                                  ("silo_global", "none")))
        self._link_ratios = {
            link: c.measure_ratio(env.params0, self.ratio_sample_elems)
            for link, c in zip(topology_mod.LINK_CLASSES,
                               self.link_codecs)}
        self._ratio = self._link_ratios["client_edge"]
        #: per-link-class wire bytes (both directions of every hop);
        #: the engine Metrics keep the flat client-link semantics
        self.link_bytes = {k: 0.0 for k in topology_mod.LINK_CLASSES}
        self._link_rng = topo.new_link_rng()

    def bootstrap(self, env: SimEnv, ctx: EngineContext) -> None:
        if self.topo is not None:
            # every silo starts round 0 at its own pace, sampling from
            # its edges' full pools (like the flat tier bootstrap)
            for s in range(self.topo.n_silos):
                self._schedule_silo(env, ctx, s)
            return
        # every tier starts round 0 at its own pace
        for m in range(env.tm.n_tiers):
            ids = env.sample_clients(env.tm.members[m],
                                     env.sc.clients_per_round, ctx.rng)
            ctx.q.push(sample_round_latency(env.tm, m, ids, ctx.rng),
                       (m, ids))

    # -- topology mode ---------------------------------------------------
    def _schedule_silo(self, env: SimEnv, ctx: EngineContext, s: int,
                       alive: Optional[np.ndarray] = None) -> bool:
        """Sample the next round for silo ``s``: per edge, draw the
        client sample and its compute latency from the engine rng (the
        same call pattern as a flat tier round, so the degenerate tree
        consumes the stream identically), then the per-link delays from
        the dedicated topology stream.  The silo's wall clock is the
        slowest edge chain (compute + client_edge + edge_silo) plus its
        skew-scaled silo_global hop.  Returns False when every edge pool
        is empty (nothing scheduled)."""
        topo = self.topo
        ids_edges, wall = [], []
        for e in range(topo.edges_per_silo):
            pool = topo.edge_members[s][e]
            if alive is not None:
                pool = pool[alive[pool]]
            ids = env.sample_clients(pool, topo.k_edge, ctx.rng)
            ids_edges.append(ids)
            wall.append(sample_round_latency(env.tm, 0, ids, ctx.rng)
                        if len(ids) else None)
        # fixed per-scheduled-round stream consumption, live or not
        ce_d, es_d, sg_d = topo.draw_delays(self._link_rng, s)
        live = [e for e in range(topo.edges_per_silo)
                if wall[e] is not None]
        if not live:
            return False
        lat = max(wall[e] + ce_d[e] + es_d[e] for e in live) + sg_d
        ctx.q.push(lat, ("silo", s, tuple(ids_edges)))
        return True

    def _refresh_dispatch(self, s: int) -> None:
        """Silo ``s`` re-fetches the current global (resample and
        blackout-return paths; the fused step refreshes in-graph on the
        committed path)."""
        self.dispatch = jax.tree.map(
            lambda d, g: d.at[s].set(g), self.dispatch, self.w_global)

    def _on_event_topology(self, env: SimEnv, ctx: EngineContext,
                           now: float, actor) -> Outcome:
        _, s, ids_edges = actor
        if not self.tier_alive[s]:
            # completed into a silo blackout: in-flight work is lost
            return Outcome.DISCARD
        alive = env.alive(now)
        done = env.completion(now)
        live = []
        for ids in ids_edges:
            ids = ids[alive[ids]]      # churned clients never reach
            if done is not None:       # their edge aggregator
                ids = ids[done[ids]]
            live.append(ids)
        n_live = int(sum(len(i) for i in live))
        if n_live == 0:                # whole silo sample dropped
            if self._schedule_silo(env, ctx, s, alive):
                self._refresh_dispatch(s)
            return Outcome.DISCARD
        mb = env.model_bytes
        ce_r = self._link_ratios["client_edge"]
        n_edges_live = sum(1 for i in live if len(i))
        # Metrics keep the flat client-link semantics (bitwise on the
        # degenerate tree); the per-class ledger counts both directions
        # of every hop: K live client payloads, one payload per live
        # edge, one per silo round
        ctx.bytes_down += n_live * mb * ce_r
        self.link_bytes["client_edge"] += 2 * n_live * mb * ce_r
        self.link_bytes["edge_silo"] += \
            2 * n_edges_live * mb * self._link_ratios["edge_silo"]
        self.link_bytes["silo_global"] += \
            2 * mb * self._link_ratios["silo_global"]
        self.counts[s] += 1
        cw = self._cross_weights()
        self.w_global, self.tier_models, self.dispatch = \
            ctx.executor.fedat_topology_round(
                self.w_global, self.tier_models, self.dispatch, s, live,
                ctx.draw_seed(), codecs=self.link_codecs,
                use_prox=self.use_prox, cross_weights=cw)
        ctx.bytes_up += n_live * mb * ce_r
        self._schedule_silo(env, ctx, s, alive)
        return Outcome.STEP

    def _cross_weights(self) -> np.ndarray:
        if not self.tier_alive.all():
            # blackout in progress elsewhere: Eq. 3 renormalizes over
            # the surviving units (runtime/elastic.py) — dead units get
            # weight exactly 0 whether weighted or uniform
            if self.weighted:
                return elastic.masked_cross_weights(self.counts,
                                                    self.tier_alive)
            return (self.tier_alive.astype(np.float32)
                    / self.tier_alive.sum())
        if self.weighted:
            return aggregation.cross_tier_weights_host(self.counts)
        return aggregation.uniform_weights_host(len(self.counts))

    def on_event(self, env: SimEnv, ctx: EngineContext, now: float,
                 actor) -> Outcome:
        if self.topo is not None:
            return self._on_event_topology(env, ctx, now, actor)
        m, ids = actor
        if not self.tier_alive[m]:
            # the round completed into a blackout: the in-flight work is
            # lost with the tier (on_fault reseeds it when it returns)
            return Outcome.DISCARD
        alive = env.alive(now)
        ids = ids[alive[ids]]
        done = env.completion(now)
        if done is not None:
            # population completion process: a sampled, still-alive client
            # can fail to return its update this round — Eq. 4 renormalizes
            # over the survivors inside the same fused step (no retrace)
            ids = ids[done[ids]]
        if len(ids) == 0:  # whole sample dropped: reschedule the tier
            pool = env.tm.members[m][alive[env.tm.members[m]]]
            ids = env.sample_clients(pool, env.sc.clients_per_round, ctx.rng)
            if len(ids):
                ctx.q.push(sample_round_latency(env.tm, m, ids, ctx.rng),
                           (m, ids))
            return Outcome.DISCARD

        # one fused device step: codec downlink -> vmapped local train ->
        # codec uplink -> Eq. 4 intra-tier average -> tier slot update ->
        # Eq. 3 cross-tier aggregation (core/executor.py); byte accounting
        # uses the *live* count, padding slots carry zero weight.  Eq. 3
        # weights come from the post-increment counts and are computed
        # eagerly (training never feeds back into them).
        ctx.bytes_down += len(ids) * env.model_bytes * self._ratio
        self.counts[m] += 1
        cw = self._cross_weights()
        gate = None if ctx.faults is None else ctx.faults.gate
        if gate is None:
            self.w_global, self.tier_models = ctx.executor.fedat_round(
                self.w_global, self.tier_models, m, ids, ctx.draw_seed(),
                codec=self.codec, use_prox=self.use_prox, cross_weights=cw)
        else:
            poison = ctx.faults.draw_poison(len(ids), ctx.executor.K)
            self.w_global, self.tier_models = ctx.executor.fedat_round(
                self.w_global, self.tier_models, m, ids, ctx.draw_seed(),
                codec=self.codec, use_prox=self.use_prox, cross_weights=cw,
                gate=gate, poison=poison)
        ctx.bytes_up += len(ids) * env.model_bytes * self._ratio

        # next round for this tier
        nxt = env.sample_clients(
            env.tm.members[m][alive[env.tm.members[m]]],
            env.sc.clients_per_round, ctx.rng)
        if len(nxt):
            ctx.q.push(sample_round_latency(env.tm, m, nxt, ctx.rng),
                       (m, nxt))
        return Outcome.STEP

    def global_params(self):
        return self.w_global

    def on_eval(self, env: SimEnv, ctx: EngineContext) -> None:
        # track the wire ratio as the weight distribution drifts (sampled)
        if self.topo is not None:
            self._link_ratios = {
                link: c.measure_ratio(self.w_global,
                                      self.ratio_sample_elems)
                for link, c in zip(topology_mod.LINK_CLASSES,
                                   self.link_codecs)}
            self._ratio = self._link_ratios["client_edge"]
            return
        self._ratio = self.codec.measure_ratio(self.w_global,
                                               self.ratio_sample_elems)

    # -- fault plane ----------------------------------------------------
    def on_fault(self, env: SimEnv, ctx: EngineContext, now: float,
                 actor) -> Outcome:
        """Tier blackout lifecycle.  Start marker: mark the tier dark and
        schedule its return; rounds completing into the blackout are
        discarded (on_event) and Eq. 3 renormalizes over the survivors.
        Return marker: the tier bootstraps from the current global model
        (the 'Eq. 3 is defined for any M' grow move, runtime/elastic.py),
        restarts its update count, and rejoins the event loop."""
        kind = actor[0]
        if kind == faults_mod.BLACKOUT:
            _, m, t_end = actor
            self.tier_alive[m] = False
            ctx.q.push(t_end - now, (faults_mod.RETURN, m))
            return Outcome.DISCARD
        m = actor[1]
        self.tier_alive[m] = True
        self.tier_models = elastic.bootstrap_tier(
            self.tier_models, self.w_global, m)
        self.counts[m] = 0
        alive = env.alive(now)
        if self.topo is not None:
            # the returning silo re-fetches the global it just
            # bootstrapped from, then rejoins the event loop
            self._refresh_dispatch(m)
            self._schedule_silo(env, ctx, m, alive)
            return Outcome.DISCARD
        ids = env.sample_clients(
            env.tm.members[m][alive[env.tm.members[m]]],
            env.sc.clients_per_round, ctx.rng)
        if len(ids):
            ctx.q.push(sample_round_latency(env.tm, m, ids, ctx.rng),
                       (m, ids))
        return Outcome.DISCARD

    # -- crash-resume ---------------------------------------------------
    def snapshot(self):
        dev = {"w_global": self.w_global, "tier_models": self.tier_models}
        host = {"counts": self.counts.copy(), "ratio": self._ratio,
                "tier_alive": self.tier_alive.copy()}
        if self.topo is not None:
            dev["dispatch"] = self.dispatch
            host["link_rng"] = self._link_rng.bit_generator.state
            host["link_bytes"] = dict(self.link_bytes)
            host["link_ratios"] = dict(self._link_ratios)
        return dev, host

    def restore(self, dev, host) -> None:
        self.w_global = dev["w_global"]
        self.tier_models = dev["tier_models"]
        self.counts = np.asarray(host["counts"], np.int64)
        self._ratio = host["ratio"]
        self.tier_alive = np.asarray(host["tier_alive"], bool)
        if self.topo is not None:
            self.dispatch = dev["dispatch"]
            self._link_rng = self.topo.new_link_rng()
            self._link_rng.bit_generator.state = host["link_rng"]
            self.link_bytes = dict(host["link_bytes"])
            self._link_ratios = dict(host["link_ratios"])
