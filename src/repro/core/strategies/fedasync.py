"""FedAsync as an engine strategy: fully asynchronous — every client
updates the server model independently with polynomial staleness weighting
(Xie et al. 2019).

Event = (client id, server version at dispatch).  A dead client's event is
discarded without rescheduling (its dropout is permanent).

``codec=None`` (default) is the paper's raw-f32 baseline link, bitwise
with the seed loop; a transport codec compresses both links like FedAT.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.compress import transport
from repro.core.engine import (EngineConfig, EngineContext, Outcome,
                               ServerStrategy)
from repro.core.simulation import SimEnv


class FedAsyncStrategy(ServerStrategy):
    name = "fedasync"
    seed_offset = 37

    def __init__(self, alpha: float = 0.6, staleness_exp: float = 0.5,
                 codec: Union[str, transport.Codec, None] = None,
                 ratio_sample_elems: Optional[int]
                 = transport.RATIO_SAMPLE_ELEMS):
        self.alpha = alpha
        self.staleness_exp = staleness_exp
        self.codec = None if codec is None else transport.get_codec(codec)
        self.ratio_sample_elems = ratio_sample_elems

    def bind(self, env: SimEnv, cfg: EngineConfig) -> None:
        # copy: the fused step may donate this buffer (executor contract)
        self.w = jax.tree.map(jnp.array, env.params0)
        self.server_version = 0
        self._ratio = (1.0 if self.codec is None else
                       self.codec.measure_ratio(env.params0,
                                                self.ratio_sample_elems))

    def bootstrap(self, env: SimEnv, ctx: EngineContext) -> None:
        # every client trains continuously at its own pace
        for c in range(env.sc.n_clients):
            ctx.q.push(float(env.tm.latencies[c]), (int(c), 0))

    def on_event(self, env: SimEnv, ctx: EngineContext, now: float,
                 actor) -> Outcome:
        c, start_version = actor
        if not env.alive(now)[c]:
            return Outcome.DISCARD
        done = env.completion(now)
        if done is not None and not done[c]:
            # population completion process: the client is up but failed to
            # finish this update — retry at its own pace, same version
            ctx.q.push(
                float(env.tm.latencies[c]) * (1 + ctx.rng.uniform(0, 0.1)),
                (c, start_version))
            return Outcome.DISCARD
        ctx.bytes_down += env.model_bytes * self._ratio
        # polynomial staleness weighting (FedAsync); the train + staleness
        # mix-in runs as one fused jitted step (core/executor.py)
        staleness = self.server_version - start_version
        a_eff = self.alpha * (1.0 + staleness) ** (-self.staleness_exp)
        self.w = ctx.executor.fedasync_round(self.w, c, a_eff,
                                             ctx.draw_seed(),
                                             codec=self.codec)
        ctx.bytes_up += env.model_bytes * self._ratio
        self.server_version += 1
        ctx.q.push(float(env.tm.latencies[c]) * (1 + ctx.rng.uniform(0, 0.1)),
                   (c, self.server_version))
        return Outcome.STEP

    def global_params(self):
        return self.w

    def on_eval(self, env: SimEnv, ctx: EngineContext) -> None:
        if self.codec is not None:  # track the drifting wire ratio, sampled
            self._ratio = self.codec.measure_ratio(self.w,
                                                   self.ratio_sample_elems)

    # -- crash-resume ---------------------------------------------------
    def snapshot(self):
        return ({"w": self.w},
                {"version": self.server_version, "ratio": self._ratio})

    def restore(self, dev, host) -> None:
        self.w = dev["w"]
        self.server_version = int(host["version"])
        self._ratio = host["ratio"]
