"""TiFL as an engine strategy: synchronous tiered rounds — pick one tier
per round (uniform random; the paper's credit scheme degenerates to this
under equal credits), FedAvg-style aggregation of that tier into the single
global model.

Differs from FedAvg only in the sampling pool and in burning the round
budget when the drawn tier has no live members (the seed loop's
``continue`` with the round counter advanced).
"""
from __future__ import annotations

from repro.core.engine import EngineContext
from repro.core.simulation import SimEnv
from repro.core.strategies.fedavg import FedAvgStrategy


class TiFLStrategy(FedAvgStrategy):
    name = "tifl"
    seed_offset = 31
    reschedule_on_empty = True

    def _sample(self, env: SimEnv, ctx: EngineContext):
        m = int(ctx.rng.integers(env.tm.n_tiers))
        alive = env.alive(ctx.q.now)
        pool = env.tm.members[m][alive[env.tm.members[m]]]
        return m, env.sample_clients(pool, env.sc.clients_per_round, ctx.rng)
