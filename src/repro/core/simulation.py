"""Shared simulation environment for all FL methods (paper §6.1 setup).

100 clients on synthetic non-i.i.d. data; latency profile with the paper's
five delay bands; 10 "unstable" clients that drop out permanently at a
random time; fixed seeds so every method sees identical partitions,
latencies, and dropout schedule.

The environment also owns the execution substrate: the device-resident
train stacks and (optionally, ``SimConfig.mesh``) the device mesh the
fused round step client-shards over — see :class:`SimEnv` and
DESIGN.md §Scale-mapping.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_mod
from repro.core import population as population_mod
from repro.core import tiering
from repro.core import topology as topology_mod
from repro.core.clients import make_client_update, make_eval_fn
from repro.runtime import sharding
from repro.data.federated import FederatedDataset, make_federated, pad_stack
from repro.models import registry as model_registry

PAPER_DELAY_BANDS = ((0.0, 0.0), (0.0, 5.0), (6.0, 10.0), (11.0, 15.0),
                     (20.0, 30.0))


@dataclasses.dataclass
class SimConfig:
    #: registered model name (models/registry.py): cnn | logreg | tiny_lm
    #: | anything registered since — the model decides the data kind
    model: str = "cnn"
    n_clients: int = 100
    n_classes: int = 10
    classes_per_client: int = 2
    samples_per_client: int = 60
    image_hw: int = 12
    n_features: int = 128
    vocab_size: int = 64           # tokens-kind models: vocabulary size
    seq_len: int = 16              # tokens-kind models: sequence length
    #: attention path for transformer-family models: "auto" | "flash" |
    #: "reference" (configs/base.py ATTENTION_BACKENDS).  "flash" routes
    #: every client step through the kernel layer; "reference" keeps the
    #: chunked-softmax parity oracle; "auto" = flash wherever available.
    attention_backend: str = "auto"
    n_tiers: int = 5
    clients_per_round: int = 10
    local_epochs: int = 3
    batch_size: int = 10
    lr: float = 1e-3
    prox_lambda: float = 0.4
    n_unstable: int = 10
    base_compute: float = 1.0      # seconds per local round before delays
    seed: int = 0
    #: "#class" (the paper's skew) or "dirichlet:<alpha>" (data/federated.py)
    partitioner: str = "#class"
    #: per-tier latency bands added on top of base_compute (paper §6.1)
    delay_bands: Tuple[Tuple[float, float], ...] = PAPER_DELAY_BANDS
    #: unstable clients drop permanently at uniform(*dropout_window)
    dropout_window: Tuple[float, float] = (50.0, 400.0)
    #: transient availability churn (core/faults.py churn_schedule): each
    #: client is a churner with probability churn_rate and gets
    #: churn_events down-windows (onsets uniform in churn_window,
    #: durations exponential with mean churn_downtime).  0.0 keeps
    #: alive() the exact permanent-dropout compare (zero-fault parity).
    churn_rate: float = 0.0
    churn_events: int = 2
    churn_downtime: float = 30.0
    churn_window: Tuple[float, float] = (50.0, 400.0)
    #: dedicated fault-plane rng stream seed (spec faults.seed) — churn
    #: draws never touch the environment rng
    fault_seed: int = 0
    #: named device mesh for the fused round step (launch/mesh.py grammar:
    #: None/"single" | "host[:n_pods]" | "production[:n_pods]").  With a
    #: data axis > 1 the per-round client fan-out is sharded over it
    #: (core/executor.py); clients_per_round must then pad to a multiple
    #: of the data-axis size.
    mesh: Optional[str] = None
    #: additionally shard the tier-model stack over the mesh's pod axis
    #: (only meaningful when the mesh has one)
    shard_tiers: bool = False
    #: population plane (core/population.py; spec section ``population``):
    #: the indexed 100k-1M-client data planes (stacked/streaming) and the
    #: FLGo-style availability/responsiveness/completion processes.  None
    #: (the spec's all-defaults section) keeps the exact legacy
    #: full-population stack — bitwise parity with the pre-population
    #: environment.
    population: Optional[population_mod.PopulationConfig] = None
    #: topology plane (core/topology.py; spec section ``topology``): the
    #: hierarchical clients -> edges -> silos -> global tree with
    #: per-link delay bands/codecs and delayed-gradient compensation.
    #: None (the spec's all-defaults section) is the exact flat FedAT
    #: engine.
    topology: Optional[topology_mod.TopologyConfig] = None


class SimEnv:
    """One materialized scenario: partitions, latencies/tiers, dropout
    schedule, model init, the device-resident data plane, and (optionally)
    the device mesh the fused round step shards over.

    ``sc.mesh`` names the mesh (launch/mesh.py grammar); with a data axis
    of size D > 1 the executor runs the per-round client stack under
    ``shard_map`` with clients split over ``data``, which requires
    ``clients_per_round % D == 0`` (checked here so misconfiguration
    fails at build time, before any compile).
    """

    def __init__(self, sc: SimConfig):
        self.sc = sc
        rng = np.random.default_rng(sc.seed)

        # device mesh for the sharded round step (None = single device);
        # resolved here (lazily per env) so importing never touches
        # jax device state.
        from repro.launch import mesh as mesh_mod
        self.mesh = mesh_mod.resolve_mesh(sc.mesh)
        # sized from this env's own mesh only — never the thread-local
        # ambient mesh (a no-mesh env built inside a use_mesh() context
        # must stay single-device)
        self.data_axis = (self.mesh.shape.get("data", 1)
                          if self.mesh is not None else 1)
        # the per-round fan-out that must pad over the data axis is the
        # per-edge sample size under the topology plane, else the flat
        # clients_per_round — the error names the spec field that failed
        k, k_field = sc.clients_per_round, "tiers.clients_per_round"
        if sc.topology is not None and sc.topology.clients_per_edge:
            k, k_field = (sc.topology.clients_per_edge,
                          "topology.clients_per_edge")
        if k % self.data_axis:
            d = self.data_axis
            raise ValueError(
                f"{k_field}={k} does not pad to a multiple of the "
                f"mesh data axis (size {d}, mesh {sc.mesh!r}); use a "
                f"multiple of {d} (e.g. {((k + d - 1) // d) * d})")
        self.rng = rng
        # the bound model (registry) decides the data kind the federated
        # partitioner synthesizes and how params/loss/eval are built
        self.model = model_registry.build_model(
            sc.model, model_registry.DataDims(
                n_classes=sc.n_classes, image_hw=sc.image_hw,
                n_features=sc.n_features, vocab_size=sc.vocab_size,
                seq_len=sc.seq_len,
                attention_backend=sc.attention_backend))
        # population plane (None = legacy full-population environment);
        # all its draws come from dedicated spec-seeded streams, so the
        # environment rng below is untouched either way
        self.population = (None if sc.population is None
                           else population_mod.Population(
                               sc.population, sc, self.model))
        #: True when per-round batches are host-materialized and streamed
        #: to the fused step instead of gathered from a resident stack
        self.streaming = (self.population is not None
                          and self.population.plane == "streaming")

        if self.population is not None and self.population.cfg.indexed:
            # indexed data plane: flat (N,) state arrays + lazy per-client
            # content streams (core/population.py); the test stack only
            # materializes the eval subset
            pop = self.population
            self.ds = None
            self.n_train_all = pop.n_train
            self.train = None if self.streaming else pop.materialize_stack()
            self.test = pop.test_stack(pop.eval_ids)
        else:
            self.ds = make_federated(
                task=self.model.data_kind, n_clients=sc.n_clients,
                n_classes=sc.n_classes,
                classes_per_client=sc.classes_per_client,
                samples_per_client=sc.samples_per_client,
                image_hw=sc.image_hw,
                n_features=sc.n_features, seed=sc.seed,
                partitioner=sc.partitioner, vocab_size=sc.vocab_size,
                seq_len=sc.seq_len)
            self.train = pad_stack(self.ds)
            self.n_train_all = self.train["n_samples"]
            self.test = self._stack_test()
            if (self.population is not None
                    and len(self.population.eval_ids) < sc.n_clients):
                ids = self.population.eval_ids
                self.test = {k: v[ids] for k, v in self.test.items()}

        # latency profile -> tiers (paper: 5 delay bands on top of compute)
        base = np.full(sc.n_clients, sc.base_compute)
        lat = tiering.profile_latencies(base, sc.delay_bands, rng)
        if (self.population is not None
                and self.population.resp_factors is not None):
            # FLGo-style responsiveness: per-client multiplicative speed
            # factors (dedicated RESP_STREAM) reshape the tier assignment
            lat = lat * self.population.resp_factors
        self.tm = tiering.assign_tiers(lat, sc.n_tiers)

        # topology plane: silo/edge membership over the same profiled
        # (responsiveness-scaled) latencies; None = flat FedAT.  Per-run
        # link-delay draw state lives on the strategy (new_link_rng), so
        # this cached env stays shareable across runs.
        self.topology = (None if sc.topology is None else
                         topology_mod.Topology(
                             sc.topology, sc.n_clients, lat,
                             sc.clients_per_round))

        # unstable clients drop permanently at a random time; the single
        # source of truth is the per-client dropout instant (+inf = stable),
        # so alive(now) is one array compare (dropout_time derives the old
        # dict view for tests that still want it)
        self.dropout_ids = rng.choice(sc.n_clients, sc.n_unstable,
                                      replace=False)
        self.dropout_at = np.full(sc.n_clients, np.inf)
        self.dropout_at[self.dropout_ids] = rng.uniform(
            *sc.dropout_window, size=sc.n_unstable)

        # transient churn windows on top of permanent dropout, drawn from
        # the dedicated fault stream (core/faults.py) so the environment
        # rng stream above is untouched; None when churn is off
        self.churn_down = faults_mod.churn_schedule(
            sc.n_clients, sc.churn_rate, sc.churn_events,
            sc.churn_downtime, sc.churn_window, sc.fault_seed)

        # model init + jitted client update / eval — all built from the
        # registry's bound FLModel over arbitrary pytree params
        key = jax.random.PRNGKey(sc.seed)
        self.params0 = self.model.init_params(key)
        self.apply_fn = self.model.apply
        # raw (un-jitted) update bodies compose inside the fused round
        # step (core/executor.py); jitting the same bodies gives the
        # standalone per-call entry points, so both paths share one trace
        # source and identical numerics.
        self.update_fn_raw = make_client_update(
            self.model, local_epochs=sc.local_epochs,
            batch_size=sc.batch_size, lr=sc.lr,
            prox_lambda=sc.prox_lambda, jit=False)
        self.update_fn_noprox_raw = make_client_update(
            self.model, local_epochs=sc.local_epochs,
            batch_size=sc.batch_size, lr=sc.lr, prox_lambda=0.0, jit=False)
        self.update_fn = jax.jit(self.update_fn_raw)
        self.update_fn_noprox = jax.jit(self.update_fn_noprox_raw)
        self.eval_fn = make_eval_fn(self.model)
        self.model_bytes = sum(np.asarray(l).nbytes
                               for l in jax.tree.leaves(self.params0))

        # device-resident data plane: the padded train stacks live on
        # device once; per-event selection is an in-graph gather
        # (core/executor.py), never a host->device copy.  Under a mesh the
        # stacks shard along the client axis (logical "clients" ->
        # physical "data", runtime/sharding.py) when the client count
        # divides evenly; otherwise they stay replicated — the gather runs
        # in the auto-sharded region, so placement is a perf choice, not a
        # correctness one.
        # (the streaming plane has no resident stacks: the executor
        # uploads one fixed-shape K-client batch per round instead)
        self.train_dev = (None if self.train is None else
                          {k: self._place_stack(self.train[k])
                           for k in ("x", "y", "mask")})
        self._test_dev = None
        self._executor = None

    def _place_stack(self, arr: np.ndarray):
        """Upload one (n_clients, ...) train stack, client-sharded when the
        mesh's data axis divides the client count."""
        if self.mesh is None or self.sc.n_clients % self.data_axis:
            return jnp.asarray(arr)
        place = sharding.logical_sharding(
            ("clients",) + (None,) * (arr.ndim - 1), self.mesh)
        return jax.device_put(arr, place)

    def _stack_test(self):
        cap = max(len(c.y_test) for c in self.ds.clients)
        n = self.ds.n_clients
        xs = np.zeros((n, cap) + self.ds.input_shape, self.ds.input_dtype)
        ys = np.zeros((n, cap), np.int32)
        mask = np.zeros((n, cap), bool)
        for i, c in enumerate(self.ds.clients):
            k = len(c.y_test)
            xs[i, :k] = c.x_test
            ys[i, :k] = c.y_test
            mask[i, :k] = True
        return {"x": xs, "y": ys, "mask": mask}

    # ------------------------------------------------------------------
    def executor(self):
        """The cached fused-round executor for this environment (the jit
        cache lives on the executor, so repeated engine runs over one env
        never recompile)."""
        if self._executor is None:
            from repro.core.executor import RoundExecutor
            self._executor = RoundExecutor(self)
        return self._executor

    @property
    def dropout_time(self) -> Dict[int, float]:
        """Dict view of the dropout schedule (derived from ``dropout_at``)."""
        return {int(c): float(self.dropout_at[c]) for c in self.dropout_ids}

    def alive(self, now: float) -> np.ndarray:
        """Per-client availability at ``now``: not permanently dropped and
        not inside a transient churn down-window.  A client sampled while
        up can be down by the time its round completes — the strategies
        re-filter on completion, which is how mid-round failures shrink
        the participant set (Eq. 4 renormalizes over survivors).  With a
        population availability process the slotted Bernoulli mask is
        folded in too (core/population.py)."""
        up = self.dropout_at > now
        if self.churn_down is not None:
            starts, ends = self.churn_down
            down = ((starts <= now) & (now < ends)).any(axis=1)
            up = up & ~down
        if self.population is not None:
            avail = self.population.availability_mask(now)
            if avail is not None:
                up = up & avail
        return up

    def completion(self, now: float) -> Optional[np.ndarray]:
        """Per-client round-completion mask at ``now`` under the
        population plane's completion process, or None when no process is
        spec'd — the strategies then keep the exact legacy
        completion-time paths (bitwise zero-population parity)."""
        if self.population is None:
            return None
        return self.population.completion_mask(now)

    def retier(self, rng: np.random.Generator, drift: float = 0.2) -> bool:
        """Re-profile client latencies (multiplicative drift) and rebuild the
        tier map (tiering.retier); returns True when any tier membership
        changed.  The engine drives this via ``EngineConfig.retier_every``
        and restores the original map at the end of the run so shared/cached
        environments stay reproducible."""
        new_lat = tiering.drift_latencies(self.tm.latencies, rng, drift)
        old = self.tm
        self.tm = tiering.retier(self.tm, new_lat)
        return any(not np.array_equal(a, b)
                   for a, b in zip(old.members, self.tm.members))

    def sample_clients(self, pool: np.ndarray, k: int,
                       rng: np.random.Generator) -> np.ndarray:
        if len(pool) == 0:
            return pool
        k = min(k, len(pool))
        return rng.choice(pool, k, replace=False)

    def client_batch(self, ids: np.ndarray) -> Dict[str, jnp.ndarray]:
        if self.train is None:  # streaming plane: materialize on demand
            return {k: jnp.asarray(v)
                    for k, v in self.population.materialize(ids).items()}
        return {k: jnp.asarray(self.train[k][ids])
                for k in ("x", "y", "mask")}

    def n_samples(self, ids: np.ndarray) -> jnp.ndarray:
        return jnp.asarray(self.n_train_all[ids])

    def data_plane_bytes(self) -> int:
        """Peak device-resident data-plane footprint in bytes: the train
        stacks (resident planes) or the streamed per-round batch buffer
        (streaming plane — the executor's high-water mark, or the static
        bound before any round ran), plus the eval test stack.  The
        streaming plane's flat-memory invariant (the bench's ``within 10%
        of the 1k-client run``) is asserted over this number."""
        test = sum(np.asarray(v).nbytes for v in self.test.values())
        if self.train_dev is not None:
            return test + sum(int(v.nbytes)
                              for v in self.train_dev.values())
        peak = (self._executor.stream_bytes
                if self._executor is not None
                and self._executor.stream_bytes else
                self.population.batch_nbytes(self.sc.clients_per_round))
        return test + peak

    def evaluate(self, params) -> Tuple[float, float]:
        """(weighted global accuracy, per-client accuracy variance)."""
        if self._test_dev is None:  # upload the test stack once
            self._test_dev = tuple(jnp.asarray(self.test[k])
                                   for k in ("x", "y", "mask"))
        accs = np.asarray(self.eval_fn(params, *self._test_dev))
        weights = self.test["mask"].sum(1)
        glob = float((accs * weights).sum() / weights.sum())
        return glob, float(np.var(accs))
