"""Executable form of FedAT's convergence analysis (paper §5, Appendix A).

Theorem 5.1 (strongly convex):  after T global updates,

    E[f(w_T) - f*] <= (1 - 2 mu B eta sigma)^T (f(w_0) - f*)
                      + (L / 2) eta^2 gamma^2 B^2 G^2 c^2

Theorem 5.2 (non-convex):

    sum_t B E[|grad f(w_t)|^2] <= (f(w_0) - f*) / (B eta sigma)
                                  + (L / (2 sigma)) T^2 eta gamma^2 B G^2 c^2

with B = T_{tier(M+1-m)} / T <= 1 the Eq. 3 weight, gamma the local
inexactness (Def. 5.3), G the gradient-norm bound (Asm. 5.2), c the tier
size, sigma the tier-gradient alignment (Asm. 5.3).

These functions make the bounds computable so tests (and users picking
eta/lambda) can check the *qualitative contracts* the paper proves:
contraction requires 2 mu B eta sigma < 1; the asymptotic error floor
scales with eta^2 gamma^2 c^2; slower tiers (larger Eq. 3 weight B) tighten
the contraction factor but widen the floor.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Regime:
    mu: float = 0.1        # strong convexity
    L: float = 1.0         # smoothness
    eta: float = 0.05      # server learning rate
    sigma: float = 1.0     # tier-gradient alignment (Asm. 5.3)
    gamma: float = 0.5     # local inexactness (Def. 5.3)
    G: float = 1.0         # gradient-norm bound (Asm. 5.2)
    c: int = 10            # clients per tier


def eq3_weight(update_counts: Sequence[float], tier: int) -> float:
    """B for ``tier`` (0-indexed): the mirror tier's share of updates."""
    counts = np.asarray(update_counts, float)
    total = counts.sum()
    if total == 0:
        return 1.0 / len(counts)
    return float(counts[::-1][tier] / total)


def contraction_factor(r: Regime, B: float) -> float:
    """(1 - 2 mu B eta sigma); < 1 required for linear convergence."""
    return 1.0 - 2.0 * r.mu * B * r.eta * r.sigma


def error_floor(r: Regime, B: float) -> float:
    """The additive term of Theorem 5.1 (per-step noise floor)."""
    return 0.5 * r.L * (r.eta ** 2) * (r.gamma ** 2) * (B ** 2) * \
        (r.G ** 2) * (r.c ** 2)


def convex_bound(r: Regime, B: float, T: int, f0_gap: float) -> float:
    """Theorem 5.1 RHS after T updates (geometric sum of the floor)."""
    rho = contraction_factor(r, B)
    if not 0.0 <= rho < 1.0:
        return math.inf
    # geometric accumulation of the per-step floor
    floor = error_floor(r, B)
    return (rho ** T) * f0_gap + floor * (1 - rho ** T) / (1 - rho)


def nonconvex_bound(r: Regime, B: float, T: int, f0_gap: float) -> float:
    """Theorem 5.2 RHS: bound on sum_t B E[|grad|^2]."""
    return f0_gap / (B * r.eta * r.sigma) + \
        0.5 * (r.L / r.sigma) * (T ** 2) * r.eta * (r.gamma ** 2) * B * \
        (r.G ** 2) * (r.c ** 2)


def max_stable_eta(r: Regime, B: float) -> float:
    """Largest eta keeping the contraction factor in (0, 1)."""
    return 1.0 / (2.0 * r.mu * B * r.sigma)


def bound_curve(r: Regime, counts: Sequence[float], T: int,
                f0_gap: float = 1.0) -> List[float]:
    """Theorem 5.1 trajectory using the *worst* per-step Eq. 3 weight
    (B varies per iteration in the paper; the worst case is the bound)."""
    Bs = [eq3_weight(counts, m) for m in range(len(counts))]
    B = max(Bs)
    return [convex_bound(r, B, t, f0_gap) for t in range(T + 1)]
