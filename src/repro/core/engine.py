"""Unified event-driven FL engine (the single loop behind every method).

The paper's protocol family — synchronous intra-tier rounds composed with
asynchronous cross-tier updates over (optionally) compressed links — and all
of its baselines are instances of one discrete-event loop:

    pop event -> (dropout filter / sampling) -> downlink -> local train
    -> uplink -> aggregate -> reschedule -> periodic eval,

with byte accounting along the two links.  What differs between FedAT,
FedAvg, TiFL and FedAsync is *server policy*: what an event means, how the
server state is aggregated, and what gets rescheduled.  Those differences
live behind the :class:`ServerStrategy` interface (FLGo's
``BasicServer.iterate()`` hook pattern, adapted to an event queue); the loop
itself lives in :func:`run_engine` and exists exactly once.

RNG discipline: a strategy declares ``seed_offset`` and draws exclusively
from ``ctx.rng`` in event order, so a (strategy, SimEnv, EngineConfig, seed)
tuple fully determines the :class:`~repro.core.scheduler.Metrics`
trajectory.  The offsets match the deleted per-method loops, keeping every
trajectory reproducible against the seed implementations
(tests/test_engine_parity.py).
"""
from __future__ import annotations

import abc
import dataclasses
import enum
import pickle
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_mod
from repro.core import tiering
from repro.core.scheduler import EventQueue, Metrics
from repro.core.simulation import SimEnv


@dataclasses.dataclass
class EngineConfig:
    """Knobs shared by every method; strategy-specific knobs live on the
    strategy object (see core/strategies/)."""
    total_updates: int = 200   # T: global update budget
    eval_every: int = 10
    seed: int = 0
    #: re-profile latencies + rebuild the tier map every N global updates
    #: (0 = never).  Draws from the engine rng, so a run with re-tiering
    #: is still fully determined by (strategy, SimEnv, EngineConfig).
    retier_every: int = 0
    #: multiplicative latency drift per re-profiling (tiering.drift_latencies)
    retier_drift: float = 0.2
    #: engine-plane fault knobs (core/faults.py FaultConfig): tier
    #: blackouts, uplink poisoning / the validation gate, and the
    #: crash-resume checkpoint cadence.  None (the default) keeps the
    #: loop byte-for-byte the zero-fault engine.
    faults: Optional[faults_mod.FaultConfig] = None


class Outcome(enum.Enum):
    """What a handled event did to the global round counter ``t``.

    STEP        committed one global update: t += 1, eval cadence applies.
    SKIP_ROUND  consumed a round of budget without an update (e.g. TiFL
                drawing a tier whose members all dropped out): t += 1 but
                no eval — mirrors the seed loops' ``continue`` after the
                round counter advanced.
    DISCARD     the event produced nothing (dead FedAsync client, FedAT
                tier resample): t unchanged.
    """
    STEP = "step"
    SKIP_ROUND = "skip_round"
    DISCARD = "discard"


@dataclasses.dataclass
class EngineContext:
    """Mutable per-run state handed to every strategy hook.

    ``executor`` is the engine-owned :class:`~repro.core.executor.
    RoundExecutor`: the fused, fixed-shape, device-resident round step
    that strategies parameterize (prox on/off, codec, aggregation
    weights).  It replaces the old per-event ``local_train`` leg — the
    whole downlink → train → uplink → aggregate pipeline now runs as one
    jitted call over resident data (DESIGN.md §Perf).  The environment's
    mesh (``SimConfig.mesh``, selected via the spec's ``mesh`` section)
    decides whether that call is single-device or client-sharded over the
    mesh's data axis (DESIGN.md §Scale-mapping); the loop itself is
    mesh-agnostic.

    ``draw_seed`` is the one host rng draw per training event; its
    position in event order is the parity contract with the seed loops.
    """
    q: EventQueue
    rng: np.random.Generator
    metrics: Metrics
    cfg: EngineConfig
    executor: Any = None
    bytes_up: float = 0.0
    bytes_down: float = 0.0
    t_global: int = 0
    #: the run's FaultPlane (core/faults.py), or None for zero-fault runs
    #: — strategies read the gate config and poison draws off it
    faults: Any = None

    def draw_seed(self) -> int:
        """The per-event PRNG seed draw (exactly one ``rng.integers``)."""
        return int(self.rng.integers(2 ** 31))


class ServerStrategy(abc.ABC):
    """Server policy plugged into :func:`run_engine`.

    Lifecycle: ``bind`` (allocate server state from the env) ->
    ``bootstrap`` (push initial events) -> ``on_event`` per popped event ->
    ``on_eval`` after each periodic evaluation.
    """

    name: str = "strategy"
    #: added to EngineConfig.seed for this strategy's rng stream; the values
    #: in core/strategies/ reproduce the seed implementations bit-for-bit.
    seed_offset: int = 0

    def bind(self, env: SimEnv, cfg: EngineConfig) -> None:
        """Allocate server-side state (models, counters) for a fresh run."""

    @abc.abstractmethod
    def bootstrap(self, env: SimEnv, ctx: EngineContext) -> None:
        """Push the initial event(s) onto ``ctx.q``."""

    @abc.abstractmethod
    def on_event(self, env: SimEnv, ctx: EngineContext, now: float,
                 actor: Any) -> Outcome:
        """Handle one completion event; return what it did to ``t``."""

    @abc.abstractmethod
    def global_params(self) -> Any:
        """The model the server would deploy right now (eval target)."""

    def on_eval(self, env: SimEnv, ctx: EngineContext) -> None:
        """Hook after each periodic eval (e.g. re-measure the wire ratio)."""

    def on_fault(self, env: SimEnv, ctx: EngineContext, now: float,
                 actor: Any) -> Outcome:
        """Handle a fault-plane marker event (core/faults.py pushes them;
        the loop routes them here instead of ``on_event``).  Default:
        ignore — strategies without a tier model treat a blackout as a
        no-op."""
        return Outcome.DISCARD

    # -- crash-resume (DESIGN.md §Fault-plane) --------------------------
    def snapshot(self):
        """(device_pytree, host_state) capturing all server state; the
        device tree round-trips through the CheckpointManager, the host
        dict through a pickle.  Bitwise resume requires *everything* the
        strategy mutates to be here."""
        raise NotImplementedError(
            f"strategy {self.name!r} does not implement engine crash-resume")

    def restore(self, dev, host) -> None:
        """Apply a :meth:`snapshot` onto a freshly bound strategy."""
        raise NotImplementedError(
            f"strategy {self.name!r} does not implement engine crash-resume")


def _engine_snapshot(ctx: EngineContext, strategy: ServerStrategy,
                     env: SimEnv) -> dict:
    """Everything a resumed run needs to replay bitwise: the strategy's
    device/host state, the event queue, the engine rng stream position,
    metrics so far, byte counters, the fault-plane stream, and the
    (possibly re-tiered) tier map.  Device arrays go through the
    CheckpointManager's array path; the host side rides along as one
    pickled uint8 leaf."""
    dev, host = strategy.snapshot()
    blob = pickle.dumps({
        "t_global": ctx.t_global,
        "bytes_up": ctx.bytes_up,
        "bytes_down": ctx.bytes_down,
        "metrics": dataclasses.asdict(ctx.metrics),
        "queue": ctx.q.state(),
        "rng": ctx.rng.bit_generator.state,
        "faults": None if ctx.faults is None else ctx.faults.state(),
        "strategy": host,
        "tm": (env.tm.tier_of, list(env.tm.members), env.tm.latencies),
    })
    return {"dev": dev, "host": np.frombuffer(blob, np.uint8)}


def _apply_engine_snapshot(snap: dict, ctx: EngineContext,
                           strategy: ServerStrategy, env: SimEnv) -> None:
    host = pickle.loads(np.asarray(snap["host"]).tobytes())
    ctx.t_global = int(host["t_global"])
    ctx.bytes_up = float(host["bytes_up"])
    ctx.bytes_down = float(host["bytes_down"])
    ctx.metrics = Metrics(**host["metrics"])
    ctx.q.set_state(host["queue"])
    ctx.rng.bit_generator.state = host["rng"]
    if ctx.faults is not None and host["faults"] is not None:
        ctx.faults.set_state(host["faults"])
    if ctx.cfg.retier_every:  # the map can only have drifted when retiering
        tier_of, members, lat = host["tm"]
        env.tm = tiering.TierMap(tier_of=tier_of, members=list(members),
                                 latencies=lat)
    # jnp.asarray preserves shapes/dtypes, so the restored state hits the
    # executor's existing compile-cache entries — zero extra recompiles
    strategy.restore(jax.tree.map(jnp.asarray, snap["dev"]),
                     host["strategy"])


def run_engine(env: SimEnv, strategy: ServerStrategy, cfg: EngineConfig,
               on_record=None, checkpoint_dir: Optional[str] = None,
               resume: bool = False) -> Metrics:
    """The one event loop.  Timestamp-ordered server reactions (Figure 1's
    timeline), a global update budget, and the shared eval cadence.

    ``on_record(point: dict)`` streams each recorded eval point to the
    caller (the api layer's ``Run.run(on_eval=...)``); the dict carries the
    same fields :meth:`~repro.core.scheduler.Metrics.record` appends.

    With ``cfg.retier_every > 0`` the environment's tier map is rebuilt
    from drifted latencies every N committed updates; the original map is
    restored on exit so shared/cached environments stay reproducible.

    Fault plane (``cfg.faults``, DESIGN.md §Fault-plane): blackout markers
    are scheduled at bootstrap and routed to ``strategy.on_fault``; with
    ``checkpoint_dir`` and ``faults.checkpoint_every > 0`` the full engine
    state is checkpointed every N committed updates through
    checkpoint/ckpt.py, and ``resume=True`` restores the newest snapshot
    (falling back to a fresh start when none exists) — the resumed run
    replays to a bitwise-identical metrics trajectory.
    """
    ctx = EngineContext(
        q=EventQueue(),
        rng=np.random.default_rng(cfg.seed + strategy.seed_offset),
        metrics=Metrics(), cfg=cfg, executor=env.executor())
    if cfg.faults is not None and cfg.faults.injects_faults:
        # blackouts strike the strategy's cross-aggregation units: flat
        # tiers, or silos under the topology plane (same marker protocol,
        # same elastic renormalization)
        topo = getattr(env, "topology", None)
        n_units = topo.n_silos if topo is not None else env.tm.n_tiers
        ctx.faults = faults_mod.FaultPlane(cfg.faults, n_units)
    strategy.bind(env, cfg)

    every = cfg.faults.checkpoint_every if cfg.faults is not None else 0
    mgr = None
    if checkpoint_dir is not None and every > 0:
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(checkpoint_dir, keep=2)

    tm0 = env.tm if cfg.retier_every else None
    resumed = False
    if mgr is not None and resume:
        try:
            snap, _ = mgr.restore(like=_engine_snapshot(ctx, strategy, env))
            _apply_engine_snapshot(snap, ctx, strategy, env)
            resumed = True
        except FileNotFoundError:
            pass  # no snapshot yet (killed before the first save)
    if not resumed:
        strategy.bootstrap(env, ctx)
        if ctx.faults is not None:
            ctx.faults.schedule(ctx.q)

    try:
        while ctx.t_global < cfg.total_updates and len(ctx.q):
            now, actor = ctx.q.pop()
            if ctx.faults is not None and faults_mod.is_fault_event(actor):
                out = strategy.on_fault(env, ctx, now, actor)
            else:
                out = strategy.on_event(env, ctx, now, actor)
            if out is Outcome.DISCARD:
                continue
            ctx.t_global += 1
            if (out is not Outcome.SKIP_ROUND
                    and (ctx.t_global % cfg.eval_every == 0
                         or ctx.t_global == cfg.total_updates)):
                acc, var = env.evaluate(strategy.global_params())
                strategy.on_eval(env, ctx)
                ctx.metrics.record(now, ctx.t_global, acc, var,
                                   ctx.bytes_up, ctx.bytes_down)
                if on_record is not None:
                    on_record({"time": now, "round": ctx.t_global,
                               "acc": acc, "acc_var": var,
                               "bytes_up": ctx.bytes_up,
                               "bytes_down": ctx.bytes_down})
            if cfg.retier_every and ctx.t_global % cfg.retier_every == 0:
                env.retier(ctx.rng, cfg.retier_drift)
            if mgr is not None and ctx.t_global % every == 0:
                mgr.save(ctx.t_global, _engine_snapshot(ctx, strategy, env))
    finally:
        if mgr is not None:
            mgr.wait()
        if tm0 is not None:
            env.tm = tm0
    return ctx.metrics


def run_strategy(env: SimEnv, name: str, cfg: EngineConfig = None,
                 **strategy_kwargs) -> Metrics:
    """Convenience: look up a registered strategy by name and run it."""
    from repro.core import strategies
    return run_engine(env, strategies.make_strategy(name, **strategy_kwargs),
                      cfg or EngineConfig())
