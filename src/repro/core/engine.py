"""Unified event-driven FL engine (the single loop behind every method).

The paper's protocol family — synchronous intra-tier rounds composed with
asynchronous cross-tier updates over (optionally) compressed links — and all
of its baselines are instances of one discrete-event loop:

    pop event -> (dropout filter / sampling) -> downlink -> local train
    -> uplink -> aggregate -> reschedule -> periodic eval,

with byte accounting along the two links.  What differs between FedAT,
FedAvg, TiFL and FedAsync is *server policy*: what an event means, how the
server state is aggregated, and what gets rescheduled.  Those differences
live behind the :class:`ServerStrategy` interface (FLGo's
``BasicServer.iterate()`` hook pattern, adapted to an event queue); the loop
itself lives in :func:`run_engine` and exists exactly once.

RNG discipline: a strategy declares ``seed_offset`` and draws exclusively
from ``ctx.rng`` in event order, so a (strategy, SimEnv, EngineConfig, seed)
tuple fully determines the :class:`~repro.core.scheduler.Metrics`
trajectory.  The offsets match the deleted per-method loops, keeping every
trajectory reproducible against the seed implementations
(tests/test_engine_parity.py).
"""
from __future__ import annotations

import abc
import dataclasses
import enum
from typing import Any

import numpy as np

from repro.core.scheduler import EventQueue, Metrics
from repro.core.simulation import SimEnv


@dataclasses.dataclass
class EngineConfig:
    """Knobs shared by every method; strategy-specific knobs live on the
    strategy object (see core/strategies/)."""
    total_updates: int = 200   # T: global update budget
    eval_every: int = 10
    seed: int = 0
    #: re-profile latencies + rebuild the tier map every N global updates
    #: (0 = never).  Draws from the engine rng, so a run with re-tiering
    #: is still fully determined by (strategy, SimEnv, EngineConfig).
    retier_every: int = 0
    #: multiplicative latency drift per re-profiling (tiering.drift_latencies)
    retier_drift: float = 0.2


class Outcome(enum.Enum):
    """What a handled event did to the global round counter ``t``.

    STEP        committed one global update: t += 1, eval cadence applies.
    SKIP_ROUND  consumed a round of budget without an update (e.g. TiFL
                drawing a tier whose members all dropped out): t += 1 but
                no eval — mirrors the seed loops' ``continue`` after the
                round counter advanced.
    DISCARD     the event produced nothing (dead FedAsync client, FedAT
                tier resample): t unchanged.
    """
    STEP = "step"
    SKIP_ROUND = "skip_round"
    DISCARD = "discard"


@dataclasses.dataclass
class EngineContext:
    """Mutable per-run state handed to every strategy hook.

    ``executor`` is the engine-owned :class:`~repro.core.executor.
    RoundExecutor`: the fused, fixed-shape, device-resident round step
    that strategies parameterize (prox on/off, codec, aggregation
    weights).  It replaces the old per-event ``local_train`` leg — the
    whole downlink → train → uplink → aggregate pipeline now runs as one
    jitted call over resident data (DESIGN.md §Perf).  The environment's
    mesh (``SimConfig.mesh``, selected via the spec's ``mesh`` section)
    decides whether that call is single-device or client-sharded over the
    mesh's data axis (DESIGN.md §Scale-mapping); the loop itself is
    mesh-agnostic.

    ``draw_seed`` is the one host rng draw per training event; its
    position in event order is the parity contract with the seed loops.
    """
    q: EventQueue
    rng: np.random.Generator
    metrics: Metrics
    cfg: EngineConfig
    executor: Any = None
    bytes_up: float = 0.0
    bytes_down: float = 0.0
    t_global: int = 0

    def draw_seed(self) -> int:
        """The per-event PRNG seed draw (exactly one ``rng.integers``)."""
        return int(self.rng.integers(2 ** 31))


class ServerStrategy(abc.ABC):
    """Server policy plugged into :func:`run_engine`.

    Lifecycle: ``bind`` (allocate server state from the env) ->
    ``bootstrap`` (push initial events) -> ``on_event`` per popped event ->
    ``on_eval`` after each periodic evaluation.
    """

    name: str = "strategy"
    #: added to EngineConfig.seed for this strategy's rng stream; the values
    #: in core/strategies/ reproduce the seed implementations bit-for-bit.
    seed_offset: int = 0

    def bind(self, env: SimEnv, cfg: EngineConfig) -> None:
        """Allocate server-side state (models, counters) for a fresh run."""

    @abc.abstractmethod
    def bootstrap(self, env: SimEnv, ctx: EngineContext) -> None:
        """Push the initial event(s) onto ``ctx.q``."""

    @abc.abstractmethod
    def on_event(self, env: SimEnv, ctx: EngineContext, now: float,
                 actor: Any) -> Outcome:
        """Handle one completion event; return what it did to ``t``."""

    @abc.abstractmethod
    def global_params(self) -> Any:
        """The model the server would deploy right now (eval target)."""

    def on_eval(self, env: SimEnv, ctx: EngineContext) -> None:
        """Hook after each periodic eval (e.g. re-measure the wire ratio)."""


def run_engine(env: SimEnv, strategy: ServerStrategy, cfg: EngineConfig,
               on_record=None) -> Metrics:
    """The one event loop.  Timestamp-ordered server reactions (Figure 1's
    timeline), a global update budget, and the shared eval cadence.

    ``on_record(point: dict)`` streams each recorded eval point to the
    caller (the api layer's ``Run.run(on_eval=...)``); the dict carries the
    same fields :meth:`~repro.core.scheduler.Metrics.record` appends.

    With ``cfg.retier_every > 0`` the environment's tier map is rebuilt
    from drifted latencies every N committed updates; the original map is
    restored on exit so shared/cached environments stay reproducible.
    """
    ctx = EngineContext(
        q=EventQueue(),
        rng=np.random.default_rng(cfg.seed + strategy.seed_offset),
        metrics=Metrics(), cfg=cfg, executor=env.executor())
    strategy.bind(env, cfg)
    strategy.bootstrap(env, ctx)

    tm0 = env.tm if cfg.retier_every else None
    try:
        while ctx.t_global < cfg.total_updates and len(ctx.q):
            now, actor = ctx.q.pop()
            out = strategy.on_event(env, ctx, now, actor)
            if out is Outcome.DISCARD:
                continue
            ctx.t_global += 1
            if (out is not Outcome.SKIP_ROUND
                    and (ctx.t_global % cfg.eval_every == 0
                         or ctx.t_global == cfg.total_updates)):
                acc, var = env.evaluate(strategy.global_params())
                strategy.on_eval(env, ctx)
                ctx.metrics.record(now, ctx.t_global, acc, var,
                                   ctx.bytes_up, ctx.bytes_down)
                if on_record is not None:
                    on_record({"time": now, "round": ctx.t_global,
                               "acc": acc, "acc_var": var,
                               "bytes_up": ctx.bytes_up,
                               "bytes_down": ctx.bytes_down})
            if cfg.retier_every and ctx.t_global % cfg.retier_every == 0:
                env.retier(ctx.rng, cfg.retier_drift)
    finally:
        if tm0 is not None:
            env.tm = tm0
    return ctx.metrics


def run_strategy(env: SimEnv, name: str, cfg: EngineConfig = None,
                 **strategy_kwargs) -> Metrics:
    """Convenience: look up a registered strategy by name and run it."""
    from repro.core import strategies
    return run_engine(env, strategies.make_strategy(name, **strategy_kwargs),
                      cfg or EngineConfig())
