"""Discrete-event scheduler driving the asynchronous FL simulation.

True cross-tier asynchrony cannot be expressed inside one SPMD program, so
the simulation uses an event queue over simulated wall-clock time: each
logical actor (a tier for FedAT/TiFL, the global round for FedAvg, a client
for FedAsync) finishes its round at ``now + latency`` and is rescheduled.
The server reacts to completion events in timestamp order — exactly the
paper's Figure 1 timeline.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, List, Optional, Tuple


@dataclasses.dataclass(order=True)
class Event:
    time: float
    seq: int
    actor: Any = dataclasses.field(compare=False)


class EventQueue:
    def __init__(self):
        self._heap: List[Event] = []
        self._counter = 0
        self.now = 0.0

    def push(self, delay: float, actor: Any) -> None:
        heapq.heappush(self._heap,
                       Event(self.now + delay, self._counter, actor))
        self._counter += 1

    def pop(self) -> Tuple[float, Any]:
        ev = heapq.heappop(self._heap)
        self.now = ev.time
        return ev.time, ev.actor

    def __len__(self) -> int:
        return len(self._heap)

    # -- crash-resume (core/faults.py): the queue must round-trip through
    # a pickle so a resumed engine replays the exact same event order
    def state(self) -> dict:
        """Serializable snapshot: heap entries (already heap-ordered),
        the monotonic tiebreak counter, and the simulated clock."""
        return {
            "heap": [(e.time, e.seq, e.actor) for e in self._heap],
            "counter": self._counter,
            "now": self.now,
        }

    def set_state(self, state: dict) -> None:
        self._heap = [Event(t, s, a) for t, s, a in state["heap"]]
        self._counter = int(state["counter"])
        self.now = float(state["now"])


@dataclasses.dataclass
class Metrics:
    """Timeline of the three robustness criteria (Definition 3.1) + cost."""
    times: List[float] = dataclasses.field(default_factory=list)
    rounds: List[int] = dataclasses.field(default_factory=list)
    acc: List[float] = dataclasses.field(default_factory=list)
    acc_var: List[float] = dataclasses.field(default_factory=list)
    bytes_up: List[float] = dataclasses.field(default_factory=list)
    bytes_down: List[float] = dataclasses.field(default_factory=list)

    def record(self, t, r, acc, var, up, down):
        self.times.append(float(t))
        self.rounds.append(int(r))
        self.acc.append(float(acc))
        self.acc_var.append(float(var))
        self.bytes_up.append(float(up))
        self.bytes_down.append(float(down))

    @property
    def best_acc(self) -> float:
        return max(self.acc) if self.acc else 0.0

    def time_to_accuracy(self, target: float) -> Optional[float]:
        for t, a in zip(self.times, self.acc):
            if a >= target:
                return t
        return None

    def bytes_to_accuracy(self, target: float) -> Optional[float]:
        for up, down, a in zip(self.bytes_up, self.bytes_down, self.acc):
            if a >= target:
                return up + down
        return None

    def summary(self) -> dict:
        return {
            "best_acc": self.best_acc,
            "final_var": self.acc_var[-1] if self.acc_var else 0.0,
            "total_mb": (self.bytes_up[-1] + self.bytes_down[-1]) / 1e6
            if self.bytes_up else 0.0,
            "sim_time": self.times[-1] if self.times else 0.0,
        }
