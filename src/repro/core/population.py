"""Population plane: indexed client state at 100k-1M scale
(DESIGN.md §Population-plane).

The legacy data plane materializes every client's samples up front
(``data/federated.make_federated`` is a *sequential* generator — client
c's draws depend on clients 0..c-1 having drawn first) and uploads the
full padded train stack to the device, which tops out around 512-2048
clients.  The population plane replaces that with an **indexed**
per-client generator plus FLGo-style stochastic client-state processes,
so a million-client federation costs a handful of flat (N,) host state
arrays while the device only ever sees fixed-shape, N-independent
buffers:

  * **Indexed content** — client c's samples come from the dedicated
    stream ``[seed, CONTENT_STREAM, c]``: any client can be materialized
    lazily, in any order, bitwise-reproducibly.  Population-level
    structure (per-client sizes, class pools / dirichlet proportions,
    class templates) is drawn *vectorized* from its own streams, so
    building a 1M-client population is a few array draws, not a loop.
  * **Static row cap** — per-client sample counts are log-normal like the
    legacy generator but clipped to ``CAP_FACTOR * samples_per_client``,
    making every materialized batch/eval buffer shape a function of the
    *config only* (the flat-memory invariant: peak device bytes do not
    grow with N).
  * **Stochastic client-state processes** (FLGo's availability /
    responsiveness / completion models): slotted Bernoulli availability
    windows folded into ``SimEnv.alive``, per-client latency multipliers
    folded into the tier profile, and a completion process the
    strategies consult when a round reports back.  All are pure
    functions of ``(spec seed, time slot)`` drawn from dedicated
    streams — replayable under crash-resume with no snapshot state, and
    inert (None) when left at their defaults so the legacy planes stay
    bitwise.

Plane selection (``PopulationConfig.plane``):

  * ``"legacy"``   — the sequential generator and full resident stack;
    with every process off this maps to ``SimConfig.population = None``
    and is byte-for-byte the pre-population environment.
  * ``"stacked"``  — the indexed generator, materialized for all N and
    device-resident.  The small-N reference the streaming plane must
    match bitwise (tests/test_population.py).
  * ``"streaming"``— the indexed generator, materialized per round for
    only the K sampled clients and passed to the fused step as data
    (core/executor.py ``_select``): flat device memory at any N.

RNG stream taxonomy: every draw family below gets its own
``default_rng([seed, STREAM, ...])`` seed sequence, so turning one knob
(say, availability) never reshuffles another family's draws — the same
dedicated-stream contract the fault plane pins (core/faults.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.data.federated import _class_templates, parse_partitioner

#: rng stream tags (seed-sequence entropy appended to ``population.seed``)
SIZE_STREAM = 0x512E5        # per-client sample counts (vectorized)
CLASS_STREAM = 0xC1A55       # class pools / dirichlet proportions
TEMPLATE_STREAM = 0x7E391    # class templates (image/features kinds)
CONTENT_STREAM = 0xC047E     # per-client sample content ([.., .., c])
AVAIL_STREAM = 0xA3A11       # slotted availability masks ([.., .., slot])
RESP_STREAM = 0x4E592        # per-client responsiveness multipliers
COMPL_STREAM = 0xC03B1       # slotted completion masks ([.., .., slot])
EVAL_STREAM = 0xE3A1C        # the eval-subset draw
PROFILE_STREAM = 0x9404E     # device-class membership (profile presets)

#: accepted data planes (PopulationConfig.plane)
PLANES = ("legacy", "stacked", "streaming")

#: static per-client row cap = CAP_FACTOR * samples_per_client (clipping
#: the log-normal size draw here is what makes device buffer shapes a
#: function of the config, not of N — the flat-memory invariant)
CAP_FACTOR = 4
#: legacy generator's size floor (data/federated.py ``max(.., 20)``)
MIN_SAMPLES = 20

#: default slot width (sim seconds) for the slotted Bernoulli processes
DEFAULT_PERIOD = 20.0

#: the ``phone`` device-class preset (``profile='phone:<frac>'``): a
#: diurnal sine availability wave, heavy-tailed responsiveness, and a
#: flaky completion process — the non-phone remainder of the population
#: stays always-on, unit-latency, and always-completing.
PHONE_AVAILABILITY = "sine:0.7,0.25,240"
PHONE_RESPONSIVENESS = "lognormal:0.5"
PHONE_COMPLETION = "bernoulli:0.9"

#: bound on cached per-slot process masks (a pure-function cache; cleared
#: wholesale when it grows past this, never invalidated)
_SLOT_CACHE_MAX = 1024


# ---------------------------------------------------------------------------
# process grammars
# ---------------------------------------------------------------------------

def parse_process(value: str, field: str, off: str):
    """``'<off>'`` -> None | ``'bernoulli:<p>[:<period>]'`` ->
    ``(p, period)`` | ``'sine:<p>,<amp>,<period>'`` ->
    ``("sine", p, amp, period)``.  Raises ValueError with the grammar.

    The sine form is a diurnal wave: within each ``DEFAULT_PERIOD``-wide
    slot the Bernoulli probability is
    ``clip(p + amp * sin(2*pi*t_mid / period), 0, 1)`` evaluated at the
    slot midpoint ``t_mid``, so availability swells and ebbs on a
    ``period``-second cycle while staying a pure function of
    ``(seed, slot)``."""
    s = str(value)
    if s == off:
        return None
    kind, _, rest = s.partition(":")
    if kind == "sine":
        try:
            p, amp, period = (float(v) for v in rest.split(","))
        except ValueError:
            raise ValueError(
                f"bad {field} process {value!r}; expected "
                f"'sine:<p>,<amp>,<period>' (e.g. 'sine:0.7,0.25,240')")
        if not 0.0 <= p <= 1.0:
            raise ValueError(
                f"{field} sine base probability must be in [0, 1], got {p}")
        if not amp >= 0:
            raise ValueError(
                f"{field} sine amplitude must be >= 0, got {amp}")
        if not period > 0:
            raise ValueError(f"{field} period must be > 0, got {period}")
        return "sine", p, amp, period
    if kind != "bernoulli":
        raise ValueError(
            f"unknown {field} process {value!r}; expected {off!r}, "
            f"'bernoulli:<p>[:<period>]' or 'sine:<p>,<amp>,<period>'")
    parts = rest.split(":") if rest else []
    if len(parts) not in (1, 2):
        raise ValueError(
            f"bad {field} process {value!r}; expected "
            f"'bernoulli:<p>[:<period>]'")
    try:
        p = float(parts[0])
        period = float(parts[1]) if len(parts) == 2 else DEFAULT_PERIOD
    except ValueError:
        raise ValueError(
            f"bad {field} process {value!r}; <p> and <period> must be "
            f"numbers (e.g. 'bernoulli:0.9:{DEFAULT_PERIOD:g}')")
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"{field} probability must be in [0, 1], got {p}")
    if not period > 0:
        raise ValueError(f"{field} period must be > 0, got {period}")
    return p, period


def parse_responsiveness(value: str):
    """``'none'`` -> None | ``'lognormal:<sigma>'`` ->
    ("lognormal", sigma) | ``'uniform:<lo>,<hi>'`` ->
    ("uniform", (lo, hi)).  Raises ValueError with the grammar."""
    s = str(value)
    if s == "none":
        return None
    kind, _, arg = s.partition(":")
    if kind == "lognormal":
        try:
            sigma = float(arg)
        except ValueError:
            raise ValueError(
                f"bad responsiveness {value!r}; expected "
                f"'lognormal:<sigma>' (e.g. 'lognormal:0.5')")
        if not sigma >= 0:
            raise ValueError(
                f"responsiveness sigma must be >= 0, got {sigma}")
        return "lognormal", sigma
    if kind == "uniform":
        try:
            lo, hi = (float(v) for v in arg.split(","))
        except ValueError:
            raise ValueError(
                f"bad responsiveness {value!r}; expected "
                f"'uniform:<lo>,<hi>' (e.g. 'uniform:0.5,2.0')")
        if not 0 < lo <= hi:
            raise ValueError(
                f"responsiveness uniform bounds must satisfy 0 < lo <= hi, "
                f"got ({lo}, {hi})")
        return "uniform", (lo, hi)
    raise ValueError(
        f"unknown responsiveness process {value!r}; expected 'none', "
        f"'lognormal:<sigma>' or 'uniform:<lo>,<hi>'")


def parse_profile(value: str) -> Optional[float]:
    """``'none'`` -> None | ``'phone:<frac>'`` -> frac in (0, 1].  A
    profile bundles the three client-state processes for a device class
    (the ``PHONE_*`` presets) applied to a seeded ``frac`` fraction of
    the population; everyone else stays always-on.  Raises ValueError
    with the grammar."""
    s = str(value)
    if s == "none":
        return None
    kind, _, arg = s.partition(":")
    if kind != "phone":
        raise ValueError(
            f"unknown population profile {value!r}; expected 'none' or "
            f"'phone:<frac>' (e.g. 'phone:0.3')")
    try:
        frac = float(arg)
    except ValueError:
        raise ValueError(
            f"bad population profile {value!r}; <frac> must be a number "
            f"(e.g. 'phone:0.3')")
    if not 0.0 < frac <= 1.0:
        raise ValueError(
            f"population profile fraction must be in (0, 1], got {frac}")
    return frac


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PopulationConfig:
    """Core-side mirror of :class:`repro.api.spec.PopulationSpec` (held on
    :class:`~repro.core.simulation.SimConfig`).  ``active`` is False iff
    every knob is at its default (``seed`` alone is inert), in which case
    the spec bridge maps the section to ``population = None`` and the
    environment builds the exact legacy plane."""
    plane: str = "legacy"             # legacy | stacked | streaming
    availability: str = "always"      # always | bernoulli:.. | sine:..
    responsiveness: str = "none"      # none | lognormal:<s> | uniform:<lo>,<hi>
    completion: str = "none"          # none | bernoulli:<p>[:<period>]
    profile: str = "none"             # none | phone:<frac> (bundled preset)
    eval_clients: int = 0             # evaluate on a seeded subset (0 = all)
    seed: int = 0                     # dedicated population rng stream seed

    @property
    def indexed(self) -> bool:
        """True when the data plane uses the indexed generator."""
        return self.plane != "legacy"

    @property
    def active(self) -> bool:
        return (self.plane != "legacy" or self.availability != "always"
                or self.responsiveness != "none" or self.completion != "none"
                or self.profile != "none" or self.eval_clients > 0)


# ---------------------------------------------------------------------------
# the population
# ---------------------------------------------------------------------------

class Population:
    """Flat per-client state arrays + the indexed sample generator + the
    stochastic client-state processes for one materialized scenario.

    The data half (sizes, class structure, templates, ``materialize``)
    is only built for the indexed planes; a ``plane="legacy"``
    population carries just the processes and the eval subset on top of
    the legacy generator's data.
    """

    def __init__(self, cfg: PopulationConfig, sc, model):
        self.cfg = cfg
        self.sc = sc
        self.n = int(sc.n_clients)
        self._seed = int(cfg.seed)
        self.plane = cfg.plane

        # -- client-state processes (pure functions of (seed, slot)) ----
        # a profile preset supplies all three process strings and a
        # seeded device-class membership mask; the spec layer rejects
        # profile + explicit processes, so there is no merge to resolve
        avail_s, resp_s, compl_s = (cfg.availability, cfg.responsiveness,
                                    cfg.completion)
        frac = parse_profile(cfg.profile)
        self._phone: Optional[np.ndarray] = None
        if frac is not None:
            rng = np.random.default_rng([self._seed, PROFILE_STREAM])
            self._phone = rng.random(self.n) < frac
            avail_s, resp_s, compl_s = (PHONE_AVAILABILITY,
                                        PHONE_RESPONSIVENESS,
                                        PHONE_COMPLETION)
        self._avail = parse_process(avail_s, "availability", off="always")
        self._compl = parse_process(compl_s, "completion", off="none")
        self._avail_cache: Dict[int, np.ndarray] = {}
        self._compl_cache: Dict[int, np.ndarray] = {}
        resp = parse_responsiveness(resp_s)
        if resp is None:
            self.resp_factors = None
        else:
            rng = np.random.default_rng([self._seed, RESP_STREAM])
            kind, arg = resp
            self.resp_factors = (rng.lognormal(0.0, arg, self.n)
                                 if kind == "lognormal"
                                 else rng.uniform(*arg, self.n))
            if self._phone is not None:
                # non-phones keep unit latency; the full-N draw happens
                # first so the phone draws don't depend on the fraction
                self.resp_factors = np.where(self._phone,
                                             self.resp_factors, 1.0)

        # -- eval subset ------------------------------------------------
        if cfg.eval_clients <= 0 or cfg.eval_clients >= self.n:
            self.eval_ids = np.arange(self.n)
        else:
            rng = np.random.default_rng([self._seed, EVAL_STREAM])
            self.eval_ids = np.sort(
                rng.choice(self.n, cfg.eval_clients, replace=False))

        # -- indexed data plane -----------------------------------------
        if not cfg.indexed:
            return
        self.kind = "features" if model.data_kind == "text" \
            else model.data_kind
        if self.kind == "tokens":
            self.shape: Tuple[int, ...] = (sc.seq_len,)
            self.dtype = np.dtype(np.int32)
            self.templates = None
        else:
            self.shape = ((sc.image_hw, sc.image_hw, 3)
                          if self.kind == "image" else (sc.n_features,))
            self.dtype = np.dtype(np.float32)
            self.templates = _class_templates(
                np.random.default_rng([self._seed, TEMPLATE_STREAM]),
                sc.n_classes, self.shape)

        #: static row caps: clipping the size draw to ``cap`` is what
        #: makes materialized buffer shapes N-independent
        self.cap = max(CAP_FACTOR * int(sc.samples_per_client), MIN_SAMPLES)
        self.cap_train = int(0.8 * self.cap)
        self.cap_test = self.cap - self.cap_train

        # per-client sizes: vectorized log-normal (legacy distribution),
        # floored at MIN_SAMPLES like the legacy generator, ceiled at cap
        rng = np.random.default_rng([self._seed, SIZE_STREAM])
        raw = rng.lognormal(np.log(sc.samples_per_client), 0.3, self.n)
        self.sizes = np.clip(raw.astype(np.int64), MIN_SAMPLES,
                             self.cap).astype(np.int32)
        #: per-client train split (the Eq. 4 sample weights + pad counts)
        self.n_train = (0.8 * self.sizes).astype(np.int32)

        # class structure: one vectorized draw for all N clients
        part_kind, alpha = parse_partitioner(sc.partitioner)
        rng = np.random.default_rng([self._seed, CLASS_STREAM])
        self.probs = None
        self.pools = None
        if part_kind == "dirichlet":
            self.probs = rng.dirichlet(np.full(sc.n_classes, alpha),
                                       size=self.n)
        elif sc.classes_per_client < sc.n_classes:
            # without-replacement pools for all clients at once: argsort
            # of a uniform matrix is a vectorized permutation per row
            u = rng.random((self.n, sc.n_classes), dtype=np.float32)
            self.pools = np.argsort(u, axis=1, kind="stable")[
                :, :sc.classes_per_client].astype(np.int32)
        # else: i.i.d. — every client draws from all classes

    # -- indexed content ------------------------------------------------
    def client_rows(self, c: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """(x, y, n_train) for client ``c`` from its dedicated content
        stream — lazily indexable, order-independent, reproducible."""
        rng = np.random.default_rng([self._seed, CONTENT_STREAM, int(c)])
        n = int(self.sizes[c])
        sc = self.sc
        if self.probs is not None:
            y = rng.choice(sc.n_classes, n, p=self.probs[c]).astype(np.int32)
        elif self.pools is not None:
            y = rng.choice(self.pools[c], n).astype(np.int32)
        else:
            y = rng.choice(sc.n_classes, n).astype(np.int32)
        if self.kind == "tokens":
            from repro.data.pipeline import class_token_sequences
            x = class_token_sequences(rng, y, sc.vocab_size, sc.seq_len)
        else:
            x = self.templates[y] + rng.normal(
                0, 1.0, size=(n,) + self.shape).astype(np.float32)
        return x, y, int(self.n_train[c])

    def materialize(self, ids: np.ndarray) -> Dict[str, np.ndarray]:
        """Padded train rows for the sampled ids: ``{x, y, mask}`` with a
        fixed ``(len(ids), cap_train, ...)`` shape.  Duplicate ids (the
        executor's dead-slot padding repeats a live id) are generated
        once and copied, so a padded round costs the live clients only."""
        ids = np.asarray(ids)
        k = len(ids)
        xs = np.zeros((k, self.cap_train) + self.shape, self.dtype)
        ys = np.zeros((k, self.cap_train), np.int32)
        mask = np.zeros((k, self.cap_train), bool)
        rows = {int(c): self.client_rows(int(c)) for c in np.unique(ids)}
        for j, c in enumerate(ids):
            x, y, n_tr = rows[int(c)]
            xs[j, :n_tr] = x[:n_tr]
            ys[j, :n_tr] = y[:n_tr]
            mask[j, :n_tr] = True
        return {"x": xs, "y": ys, "mask": mask}

    def materialize_stack(self) -> Dict[str, np.ndarray]:
        """The full resident train stack (the ``stacked`` plane): the same
        rows ``materialize`` streams, for all N clients, plus the legacy
        ``n_samples`` key for the eager helpers."""
        stack = self.materialize(np.arange(self.n))
        stack["n_samples"] = self.n_train.copy()
        return stack

    def test_stack(self, ids: np.ndarray) -> Dict[str, np.ndarray]:
        """Padded per-client test rows for ``ids`` (the eval subset) in
        the same layout as :meth:`SimEnv._stack_test`."""
        ids = np.asarray(ids)
        k = len(ids)
        xs = np.zeros((k, self.cap_test) + self.shape, self.dtype)
        ys = np.zeros((k, self.cap_test), np.int32)
        mask = np.zeros((k, self.cap_test), bool)
        for j, c in enumerate(ids):
            x, y, n_tr = self.client_rows(int(c))
            t = len(y) - n_tr
            xs[j, :t] = x[n_tr:]
            ys[j, :t] = y[n_tr:]
            mask[j, :t] = True
        return {"x": xs, "y": ys, "mask": mask}

    def batch_nbytes(self, k: int) -> int:
        """Host/device bytes of one materialized k-client round batch (the
        streaming plane's peak data-plane footprint)."""
        row = (int(np.prod(self.shape, dtype=np.int64)) * self.dtype.itemsize
               + np.dtype(np.int32).itemsize + np.dtype(bool).itemsize)
        return int(k) * self.cap_train * row

    # -- processes ------------------------------------------------------
    def _slot_mask(self, now: float, proc, stream: int,
                   cache: Dict[int, np.ndarray]) -> Optional[np.ndarray]:
        if proc is None:
            return None
        if proc[0] == "sine":
            # diurnal wave: DEFAULT_PERIOD-wide slots, probability
            # evaluated at the slot midpoint of the sine cycle
            _, p0, amp, period = proc
            slot = int(now // DEFAULT_PERIOD)
            m = cache.get(slot)
            if m is None:
                if len(cache) > _SLOT_CACHE_MAX:
                    cache.clear()
                mid = (slot + 0.5) * DEFAULT_PERIOD
                p = float(np.clip(
                    p0 + amp * np.sin(2.0 * np.pi * mid / period), 0.0, 1.0))
                m = np.random.default_rng(
                    [self._seed, stream, slot]).random(self.n) < p
                cache[slot] = m
            return m
        p, period = proc
        slot = int(now // period)
        m = cache.get(slot)
        if m is None:
            if len(cache) > _SLOT_CACHE_MAX:
                cache.clear()
            m = np.random.default_rng(
                [self._seed, stream, slot]).random(self.n) < p
            cache[slot] = m
        return m

    def availability_mask(self, now: float) -> Optional[np.ndarray]:
        """(N,) bool availability at ``now`` (slotted Bernoulli or sine),
        or None when the process is off — ``SimEnv.alive`` then keeps the
        exact legacy expression.  Under a device-class profile the
        process only gates the profiled class; everyone else stays on."""
        m = self._slot_mask(now, self._avail, AVAIL_STREAM,
                            self._avail_cache)
        if m is not None and self._phone is not None:
            m = m | ~self._phone
        return m

    def completion_mask(self, now: float) -> Optional[np.ndarray]:
        """(N,) bool round-completion mask at ``now``, or None when the
        process is off.  Consulted by the strategies when a round reports
        back: a sampled, still-alive client can fail to return its
        update, shrinking the participant set (Eq. 4 renormalizes over
        the survivors inside the same fused step — no retrace).  Under a
        profile, non-profiled clients always complete."""
        m = self._slot_mask(now, self._compl, COMPL_STREAM,
                            self._compl_cache)
        if m is not None and self._phone is not None:
            m = m | ~self._phone
        return m
