"""Client-side local training (FedAT §4.2), generic over registry models.

Each selected client k minimizes the proximal surrogate (Eq. 5):

    h_k(w_k) = F_k(w_k) + (lambda/2) ||w_k - w_global||^2

where F_k is the bound model's own objective
(:class:`repro.models.registry.FLModel` ``loss`` — classification CE for
the paper models, next-token CE for LMs) and the proximal term is
pytree-generic (any params structure ``jax.tree`` traverses), with a
local Adam solver (paper hyperparameters: E epochs, batch 10).  Client
updates are *vmapped*: all selected clients of a tier train in one jitted
call over stacked (client, sample, ...) arrays with sample masks — this
is what makes the 100-client simulation fast on CPU and is exactly the
batched-lowering pattern a TPU deployment would use.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def make_client_update(
    model,
    local_epochs: int = 3,
    batch_size: int = 10,
    lr: float = 1e-3,
    prox_lambda: float = 0.4,
    max_samples: int = 128,
    solver: str = "adam",
    jit: bool = True,
) -> Callable:
    """Returns update(global_params, client_batch, rng) vmapped over clients.

    ``model`` is a bound :class:`repro.models.registry.FLModel`;
    client_batch: {"x": (C, N, ...), "y": (C, N), "mask": (C, N)}.
    Output: (client_params stacked (C, ...), local loss (C,)).

    ``jit=False`` returns the un-jitted body so callers can compose it
    inside a larger jitted program (the fused round step in
    core/executor.py); ``jax.jit`` of that body is the ``jit=True`` fn.
    """

    def loss_fn(params, global_params, x, y, mask):
        ce = model.loss(params, x, y, mask)
        prox = 0.5 * prox_lambda * sum(
            jnp.sum(jnp.square(a - b)) for a, b in zip(
                jax.tree.leaves(params), jax.tree.leaves(global_params)))
        return ce + prox, ce

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def one_client(global_params, x, y, mask, rng):
        n = x.shape[0]
        n_batches = max(n // batch_size, 1)

        params = global_params
        if solver == "adam":
            m = jax.tree.map(jnp.zeros_like, params)
            v = jax.tree.map(jnp.zeros_like, params)
            opt = (m, v, jnp.zeros((), jnp.int32))
        else:
            opt = None

        def epoch_body(carry, ep_rng):
            params, opt = carry
            perm = jax.random.permutation(ep_rng, n)

            def batch_body(carry, i):
                params, opt = carry
                idx = jax.lax.dynamic_slice_in_dim(perm, i * batch_size,
                                                   batch_size)
                xb, yb, mb = x[idx], y[idx], mask[idx]
                (_, ce), grads = grad_fn(params, global_params, xb, yb, mb)
                if solver == "adam":
                    m, v, cnt = opt
                    cnt = cnt + 1
                    m = jax.tree.map(lambda a, g: 0.9 * a + 0.1 * g, m, grads)
                    v = jax.tree.map(
                        lambda a, g: 0.999 * a + 0.001 * jnp.square(g), v,
                        grads)
                    c1 = 1 - 0.9 ** cnt.astype(jnp.float32)
                    c2 = 1 - 0.999 ** cnt.astype(jnp.float32)
                    params = jax.tree.map(
                        lambda p, m_, v_: p - lr * (m_ / c1) /
                        (jnp.sqrt(v_ / c2) + 1e-8), params, m, v)
                    opt = (m, v, cnt)
                else:
                    params = jax.tree.map(lambda p, g: p - lr * g, params,
                                          grads)
                return (params, opt), ce

            (params, opt), ces = jax.lax.scan(
                batch_body, (params, opt), jnp.arange(n_batches))
            return (params, opt), jnp.mean(ces)

        rngs = jax.random.split(rng, local_epochs)
        (params, _), losses = jax.lax.scan(epoch_body, (params, opt), rngs)
        return params, losses[-1]

    def update(global_params, batch, rngs):
        fn = lambda x, y, m, r: one_client(global_params, x, y, m, r)
        return jax.vmap(fn)(batch["x"], batch["y"], batch["mask"], rngs)

    return jax.jit(update) if jit else update


def make_eval_fn(model) -> Callable:
    """Per-client test accuracy, vmapped: (params, x (C,N,...), y, mask);
    the metric itself is the bound model's ``eval_metrics``."""

    @jax.jit
    def evaluate(params, x, y, mask):
        def one(x_, y_, m_):
            return model.eval_metrics(params, x_, y_, m_)
        return jax.vmap(one)(x, y, mask)

    return evaluate
