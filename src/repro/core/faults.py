"""Deterministic fault plane: spec-driven fault injection for the engine.

FedAT's premise is that at scale *something is always slow or gone*; the
fault plane makes that a first-class, reproducible part of a scenario
(DESIGN.md §Fault-plane).  The spec's ``faults`` section
(:class:`repro.api.spec.FaultSpec`) drives four fault families:

  * **transient client churn** — per-client availability *windows* (down
    intervals) layered on top of the permanent dropout schedule; a client
    sampled while up can be down by the time its round completes, which
    shrinks the participant set so Eq. 4 renormalizes over the survivors
    inside the same jitted round step (the executor's fixed-shape padding
    contract — no retrace);
  * **tier blackouts** — a whole tier disappears for an interval; the
    FedAT strategy renormalizes Eq. 3 over the surviving M' tiers
    (runtime/elastic.py) and the returning tier bootstraps from the
    global model;
  * **poisoned uplinks** — a client's decoded update is replaced with
    NaN; the server-side validation gate (core/steps.py) zero-weights it
    and renormalizes, so one bad client degrades a round instead of
    sinking the global model;
  * **crash-resume** — ``run_engine`` checkpoints full engine state every
    N committed updates (core/engine.py) so a killed run resumes to a
    bitwise-identical metrics trajectory.

RNG stream contract: every fault draw comes from a *dedicated*
spec-seeded stream (seeded ``[faults.seed, <stream tag>]``), never from
the engine's event-order rng or the environment's materialization rng.
A zero-fault spec therefore stays bitwise identical to the fault-plane-
free engine: ``alive()`` reduces to the permanent-dropout compare, no
marker events enter the queue, and the ungated round steps compile from
the exact pre-fault-plane bodies (tests/test_engine_parity.py is the
oracle).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import numpy as np

#: rng stream tags (seed-sequence entropy appended to ``faults.seed``) —
#: churn windows and event-time draws are independent streams so adding
#: blackout/poison knobs never reshuffles the churn schedule
CHURN_STREAM = 0xC4312
EVENT_STREAM = 0xFA417

#: queue-actor tags for fault marker events (engine routes these to
#: ``ServerStrategy.on_fault`` instead of ``on_event``)
BLACKOUT = "fault_blackout"
RETURN = "fault_return"
_FAULT_KINDS = (BLACKOUT, RETURN)


def is_fault_event(actor: Any) -> bool:
    """True for fault-plane marker actors (pushed by :meth:`FaultPlane.
    schedule` / the strategy's blackout handling)."""
    return (isinstance(actor, tuple) and len(actor) > 0
            and actor[0] in _FAULT_KINDS)


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Engine-plane fault knobs (the churn knobs live on
    :class:`~repro.core.simulation.SimConfig` — availability windows are
    part of the materialized environment).  Mirrors the strategy/engine
    subset of :class:`repro.api.spec.FaultSpec`."""
    blackouts: int = 0
    blackout_duration: float = 60.0
    blackout_window: Tuple[float, float] = (50.0, 400.0)
    nan_rate: float = 0.0
    update_clip: float = 0.0
    checkpoint_every: int = 0
    seed: int = 0

    @property
    def injects_faults(self) -> bool:
        """Any knob that perturbs the trajectory (needs a FaultPlane)."""
        return (self.blackouts > 0 or self.nan_rate > 0
                or self.update_clip > 0)

    @property
    def active(self) -> bool:
        """Anything at all for the engine to do (faults or checkpoints)."""
        return self.injects_faults or self.checkpoint_every > 0


class FaultPlane:
    """Per-run fault state: the dedicated event-draw rng stream, the
    blackout schedule (drawn up front, so it is a pure function of the
    spec), and the uplink-poison draws.  Held on
    :class:`~repro.core.engine.EngineContext` as ``ctx.faults`` (None for
    zero-fault runs) and snapshotted/restored for crash-resume."""

    def __init__(self, cfg: FaultConfig, n_tiers: int):
        self.cfg = cfg
        self.rng = np.random.default_rng([int(cfg.seed), EVENT_STREAM])
        #: (start, end, tier) blackout intervals, start-sorted
        self.blackout_events = []
        for _ in range(cfg.blackouts):
            m = int(self.rng.integers(n_tiers))
            t0 = float(self.rng.uniform(*cfg.blackout_window))
            self.blackout_events.append(
                (t0, t0 + float(cfg.blackout_duration), m))
        self.blackout_events.sort()
        self._gate = None

    # ------------------------------------------------------------------
    def schedule(self, q) -> None:
        """Push the blackout-start markers at bootstrap (queue ``now`` is
        0, so the drawn start times are absolute).  Strategies that model
        tiers (FedAT) handle the markers in ``on_fault``; others inherit
        the ignore default."""
        for t0, t1, m in self.blackout_events:
            q.push(t0, (BLACKOUT, m, t1))

    @property
    def gate(self):
        """The server-side update validation gate config
        (:class:`~repro.core.steps.UpdateGate`), or None when neither
        poison injection nor norm clipping is spec'd — the ungated
        (parity-oracle) round steps are then compiled."""
        if self.cfg.nan_rate <= 0 and self.cfg.update_clip <= 0:
            return None
        if self._gate is None:
            from repro.core.steps import UpdateGate
            self._gate = UpdateGate(clip_norm=float(self.cfg.update_clip))
        return self._gate

    def draw_poison(self, n_live: int, k: int) -> np.ndarray:
        """(k,) bool mask: with probability ``nan_rate`` one of the
        ``n_live`` leading (live) slots is poisoned this round.  Exactly
        one ``rng.random()`` per gated training event (plus one
        ``integers`` when triggered) keeps the stream replayable."""
        mask = np.zeros(k, bool)
        if self.cfg.nan_rate <= 0:
            return mask
        if n_live > 0 and self.rng.random() < self.cfg.nan_rate:
            mask[int(self.rng.integers(n_live))] = True
        return mask

    # -- crash-resume ---------------------------------------------------
    def state(self) -> dict:
        """Serializable stream position (the blackout schedule is a pure
        function of the config, so only the event-draw rng needs saving)."""
        return {"rng": self.rng.bit_generator.state}

    def set_state(self, state: dict) -> None:
        self.rng.bit_generator.state = state["rng"]


def churn_schedule(n_clients: int, rate: float, events: int,
                   downtime: float, window: Tuple[float, float],
                   seed: int) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Transient-availability windows: each client is a churner with
    probability ``rate``; a churner gets ``events`` down intervals whose
    onsets are uniform in ``window`` and whose durations are exponential
    with mean ``downtime``.

    Returns ``(starts, ends)`` of shape (n_clients, events) with +inf
    rows for non-churners, or None when churn is off — the off case lets
    :meth:`SimEnv.alive` keep the exact pre-fault-plane expression
    (bitwise zero-fault parity).  Draws come from the dedicated
    ``[seed, CHURN_STREAM]`` stream, never the environment rng.
    """
    if rate <= 0 or events <= 0:
        return None
    rng = np.random.default_rng([int(seed), CHURN_STREAM])
    churner = rng.random(n_clients) < rate
    starts = np.full((n_clients, events), np.inf)
    ends = np.full((n_clients, events), np.inf)
    lo, hi = window
    for i in np.flatnonzero(churner):
        s = np.sort(rng.uniform(lo, hi, events))
        starts[i] = s
        ends[i] = s + rng.exponential(downtime, events)
    return starts, ends
