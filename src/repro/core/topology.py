"""Topology plane: hierarchical geo-distributed federation (DESIGN.md
§Topology-plane).

FedAT's flat layout is one hop: clients -> tiered server.  Production FL
is cross-device *and* cross-silo (Papaya, PAPERS.md): clients talk to a
nearby **edge** aggregator over a LAN-ish link, edges feed a regional
**silo**, and silos update the **global** server over WAN.  This module
is the declarative tree plus its deterministic network model:

* three **link classes** — ``client_edge``, ``edge_silo``,
  ``silo_global`` — each with its own delay band (drawn from the
  dedicated ``LINK_STREAM`` spec rng stream, so the population/fault
  planes' streams are untouched) and its own codec from the transport
  registry (WAN hops can compress harder than LAN hops, with per-link
  wire bytes accounted separately by the strategy);
* **region skew for free** — silos take contiguous client-id blocks, so
  under the ``#classes`` partitioner each silo sees a different label
  slice; edges within a silo are latency-tiered via
  :func:`~repro.core.tiering.assign_tiers`;
* a deterministic **WAN skew ramp** — silo ``s`` multiplies its
  ``silo_global`` delay by ``1 + silo_skew * s``, so "the slow region"
  is a spec knob, not a roll of the dice;
* **delayed-gradient compensation** ("Stragglers Are Not Disaster",
  PAPERS.md): a silo trains from the global model it fetched at
  dispatch time; with ``compensation = lam > 0`` its update is corrected
  by ``lam * (w_now - w_dispatch)`` before entering Eq. 3, so stale
  silo updates are *repaired* rather than merely down-weighted.

The bitwise contract (pinned in tests/test_topology.py): an absent
``topology`` section changes nothing, and the degenerate
single-silo/single-edge tree with zero-delay bands and default codecs is
bitwise-identical to the flat FedAT run with ``n_tiers=1`` — the extra
aggregation levels collapse to exact identities (x1.0 weighted averages
over singleton stacks), and zero-width uniform bands draw exactly 0.0
while still consuming their stream.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from repro.core import tiering

#: the three hop classes of the clients -> edges -> silos -> global tree;
#: spec ``topology.delay`` / ``topology.codec`` dicts are keyed by these.
LINK_CLASSES = ("client_edge", "edge_silo", "silo_global")

#: dedicated rng stream for per-round link-delay draws
#: (``default_rng([seed, LINK_STREAM])``) — engine event order and the
#: population/fault streams never shift when delay bands change.
LINK_STREAM = 0x70B0A


@dataclass(frozen=True)
class TopologyConfig:
    """SimConfig payload for the topology plane (built by
    ``TopologySpec.to_config``; ``None`` on SimConfig = flat FedAT)."""
    n_silos: int = 1
    edges_per_silo: int = 1
    #: clients sampled per edge per round (0 = inherit
    #: ``tiers.clients_per_round``)
    clients_per_edge: int = 0
    #: ((link_class, lo, hi), ...) uniform delay bands in sim-time units
    delay: Tuple[Tuple[str, float, float], ...] = ()
    #: ((link_class, codec_name), ...) per-link codec overrides
    codec: Tuple[Tuple[str, str], ...] = ()
    #: delayed-gradient compensation strength lam in [0, 1]
    compensation: float = 0.0
    #: silo s multiplies its silo_global delay by ``1 + silo_skew * s``
    silo_skew: float = 0.0
    seed: int = 0

    def delay_band(self, link: str) -> Tuple[float, float]:
        for name, lo, hi in self.delay:
            if name == link:
                return float(lo), float(hi)
        return 0.0, 0.0

    def codec_name(self, link: str, default: str) -> str:
        for name, codec in self.codec:
            if name == link:
                return codec
        return default


class Topology:
    """The materialized tree: silo/edge membership over concrete client
    ids plus the link-delay model.  Built once per SimEnv (pure function
    of the config + the latency profile); all per-run draw *state* lives
    on the strategy via :meth:`new_link_rng` so cached envs stay
    shareable across runs.
    """

    def __init__(self, cfg: TopologyConfig, n_clients: int,
                 latencies: np.ndarray, k_round: int):
        S, E = cfg.n_silos, cfg.edges_per_silo
        if S * E > n_clients:
            raise ValueError(
                f"topology needs n_silos*edges_per_silo <= n_clients "
                f"({S}*{E} > {n_clients})")
        self.cfg = cfg
        self.n_silos = S
        self.edges_per_silo = E
        self.k_edge = int(cfg.clients_per_edge or k_round)
        # contiguous id blocks per silo: under the #classes partitioner
        # client order tracks label structure, so silos = skewed regions
        self.silo_members = [np.asarray(m) for m in
                             np.array_split(np.arange(n_clients), S)]
        # edges within a silo are latency tiers over the silo's members
        self.edge_members = []
        for mem in self.silo_members:
            tm = tiering.assign_tiers(latencies[mem], E)
            self.edge_members.append([mem[ids] for ids in tm.members])
        self.silo_mult = 1.0 + cfg.silo_skew * np.arange(S, dtype=np.float64)

    def new_link_rng(self) -> np.random.Generator:
        """Fresh per-run link-delay stream (strategy-owned, snapshotted
        for bitwise crash-resume)."""
        return np.random.default_rng([self.cfg.seed, LINK_STREAM])

    def draw_delays(self, rng: np.random.Generator, silo: int):
        """One scheduled silo round's link delays, in a fixed draw order
        (client_edge x E, edge_silo x E, silo_global x 1) so consumption
        per round is constant regardless of which edges sampled empty.
        Zero-width bands draw exactly 0.0 (numpy uniform(0, 0) == 0.0)
        while still advancing the stream."""
        E = self.edges_per_silo
        ce_lo, ce_hi = self.cfg.delay_band("client_edge")
        es_lo, es_hi = self.cfg.delay_band("edge_silo")
        sg_lo, sg_hi = self.cfg.delay_band("silo_global")
        ce = rng.uniform(ce_lo, ce_hi, E)
        es = rng.uniform(es_lo, es_hi, E)
        sg = float(rng.uniform(sg_lo, sg_hi)) * float(self.silo_mult[silo])
        return ce, es, sg
