from repro.data.federated import (  # noqa: F401
    ClientData, FederatedDataset, global_test_set, make_federated, pad_stack)
from repro.data.pipeline import TokenPipeline  # noqa: F401
