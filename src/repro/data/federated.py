"""Synthetic federated datasets with controllable non-i.i.d.-ness.

No network access in this environment, so the paper's CIFAR-10 /
Fashion-MNIST / Sentiment140 are modeled by deterministic synthetic
class-conditional datasets with the same *federated structure*:

  * ``#class`` partitioning — each client holds samples from exactly
    ``classes_per_client`` labels (the paper's 2/4/6/8-class splits),
  * ``dirichlet:<alpha>`` partitioning — per-client label distributions
    drawn from Dir(alpha); small alpha = heavy skew (Hsu et al. 2019),
  * unequal client sizes (log-normal), 80/20 train/test split per client,
  * "image" kind: class-template + noise images (CNN-learnable),
  * "features" kind: class-conditional feature vectors (logreg-learnable),
  * "tokens" kind: class-conditional Markov token streams
    (data/pipeline.py; tiny-LM-learnable next-token structure).

A registered model (models/registry.py) declares which kind it consumes
via ``FLModel.data_kind``; the partitioners are kind-agnostic.  The
generator is seeded, so every FL method trains on byte-identical
partitions (the paper's fixed pseudo-random mini-batch schedule), and the
image/features draw order is identical to the pre-registry ``task``
generator (bitwise parity contract).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass
class ClientData:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_train(self) -> int:
        return len(self.y_train)


@dataclasses.dataclass
class FederatedDataset:
    clients: List[ClientData]
    n_classes: int
    input_shape: Tuple[int, ...]
    #: dtype of the per-sample inputs (float32 images/features, int32
    #: token sequences); pad_stack and the test stacks honor it
    input_dtype: np.dtype = np.float32

    @property
    def n_clients(self) -> int:
        return len(self.clients)


def _class_templates(rng, n_classes, shape, scale=2.0):
    return rng.normal(0.0, scale, size=(n_classes,) + shape).astype(np.float32)


def parse_partitioner(partitioner: str) -> Tuple[str, float]:
    """``'#class'`` -> ("#class", 0) | ``'dirichlet:<alpha>'`` ->
    ("dirichlet", alpha).  Raises ValueError with the accepted grammar."""
    kind, _, arg = str(partitioner).partition(":")
    if kind == "#class":
        return "#class", 0.0
    if kind == "dirichlet":
        try:
            alpha = float(arg) if arg else 0.5
        except ValueError:
            raise ValueError(
                f"bad dirichlet concentration in partitioner "
                f"{partitioner!r} (expected e.g. 'dirichlet:0.3')")
        if not alpha > 0:
            raise ValueError(f"dirichlet alpha must be > 0, got {alpha}")
        return "dirichlet", alpha
    raise ValueError(f"unknown partitioner {partitioner!r}; expected "
                     f"'#class' or 'dirichlet:<alpha>'")


#: accepted data kinds; "text" is the pre-registry alias for "features"
DATA_KINDS = ("image", "features", "tokens")


def make_federated(
    task: str = "image",
    n_clients: int = 100,
    n_classes: int = 10,
    classes_per_client: int = 2,
    samples_per_client: int = 100,
    image_hw: int = 12,
    n_features: int = 128,
    noise: float = 1.0,
    seed: int = 0,
    partitioner: str = "#class",
    vocab_size: int = 64,
    seq_len: int = 16,
) -> FederatedDataset:
    """``task`` is the data kind (``DATA_KINDS``; "text" aliases
    "features" for pre-registry callers).  ``#class``:
    classes_per_client >= n_classes => i.i.d. (uniform over all classes).
    ``dirichlet:<alpha>``: per-client class proportions drawn from
    Dir(alpha); classes_per_client is ignored."""
    data_kind = "features" if task == "text" else task
    if data_kind not in DATA_KINDS:
        raise ValueError(f"unknown data kind {task!r}; "
                         f"expected one of {DATA_KINDS} (or 'text')")
    kind, alpha = parse_partitioner(partitioner)
    rng = np.random.default_rng(seed)
    if data_kind == "tokens":
        shape, dtype = (seq_len,), np.int32
        templates = None
    else:
        shape = ((image_hw, image_hw, 3) if data_kind == "image"
                 else (n_features,))
        dtype = np.float32
        templates = _class_templates(rng, n_classes, shape)

    clients = []
    for c in range(n_clients):
        if kind == "dirichlet":
            p = rng.dirichlet(np.full(n_classes, alpha))
            n = max(int(rng.lognormal(np.log(samples_per_client), 0.3)), 20)
            y = rng.choice(n_classes, n, p=p).astype(np.int32)
        else:
            # the seed ``#class`` path: draw order must stay byte-identical
            if classes_per_client >= n_classes:
                labels_pool = np.arange(n_classes)
            else:
                labels_pool = rng.choice(n_classes, classes_per_client,
                                         replace=False)
            n = max(int(rng.lognormal(np.log(samples_per_client), 0.3)), 20)
            y = rng.choice(labels_pool, n).astype(np.int32)
        if data_kind == "tokens":
            from repro.data.pipeline import class_token_sequences
            x = class_token_sequences(rng, y, vocab_size, seq_len)
        else:
            x = templates[y] + rng.normal(
                0, noise, size=(n,) + shape).astype(np.float32)
        n_tr = int(0.8 * n)
        clients.append(ClientData(x[:n_tr], y[:n_tr], x[n_tr:], y[n_tr:]))
    return FederatedDataset(clients, n_classes, shape, np.dtype(dtype))


def pad_stack(ds: FederatedDataset, max_samples: int = 0
              ) -> Dict[str, np.ndarray]:
    """Stack clients into dense arrays (vmap-able): pads with sample masks."""
    cap = max_samples or max(c.n_train for c in ds.clients)
    n = ds.n_clients
    xs = np.zeros((n, cap) + ds.input_shape, ds.input_dtype)
    ys = np.zeros((n, cap), np.int32)
    mask = np.zeros((n, cap), bool)
    for i, c in enumerate(ds.clients):
        k = min(c.n_train, cap)
        xs[i, :k] = c.x_train[:k]
        ys[i, :k] = c.y_train[:k]
        mask[i, :k] = True
    return {"x": xs, "y": ys, "mask": mask,
            "n_samples": mask.sum(1).astype(np.int32)}


def global_test_set(ds: FederatedDataset) -> Tuple[np.ndarray, np.ndarray]:
    xs = np.concatenate([c.x_test for c in ds.clients])
    ys = np.concatenate([c.y_test for c in ds.clients])
    return xs, ys
