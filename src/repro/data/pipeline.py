"""Deterministic synthetic LM data pipeline for the large-model drivers.

Generates token streams from a seeded Markov-ish process (cheap, infinite,
reproducible across restarts via the step counter — resuming from a
checkpoint replays the exact stream position).  Provides host-side batching
with prefetch and per-shape batch builders matching lm.input_specs().
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig


def class_token_sequences(rng: np.random.Generator, labels: np.ndarray,
                          vocab_size: int, seq_len: int,
                          noise: float = 0.1) -> np.ndarray:
    """Class-conditional token streams for the federated LM path.

    One (seq_len,) int32 sequence per label: class c walks the vocab
    cyclically with stride ``1 + (c % (V-1))`` from a random start, with a
    ``noise`` fraction of positions resampled uniformly.  Next-token
    structure is therefore a per-class affine map — learnable by a tiny
    causal LM, non-i.i.d. across clients exactly like the image/feature
    tasks (the partitioner decides which classes a client holds).
    ``make_federated(kind="tokens")`` (data/federated.py) routes through
    here, wiring this pipeline into the federated partitioner.
    """
    labels = np.asarray(labels)
    n = len(labels)
    starts = rng.integers(0, vocab_size, n)
    steps = 1 + (labels % max(vocab_size - 1, 1))
    pos = np.arange(seq_len)
    toks = (starts[:, None] + steps[:, None] * pos[None, :]) % vocab_size
    resample = rng.random((n, seq_len)) < noise
    toks = np.where(resample, rng.integers(0, vocab_size, (n, seq_len)),
                    toks)
    return toks.astype(np.int32)


class TokenPipeline:
    """Stateless-per-step synthetic token source: batch(step) is pure."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg, shape = self.cfg, self.shape
        rng = np.random.default_rng((self.seed, step))
        B, S = shape.global_batch, shape.seq_len
        if cfg.family == "vlm":
            np_ = min(cfg.n_frontend_tokens, S // 2)
            return {
                "patch_embeds": rng.normal(
                    0, 1, (B, np_, cfg.d_model)).astype(np.float32),
                "tokens": rng.integers(
                    0, cfg.vocab_size, (B, S - np_)).astype(np.int32),
            }
        if cfg.family == "audio":
            mask = rng.random((B, S)) < 0.08
            return {
                "frames": rng.normal(0, 1, (B, S, cfg.d_model)).astype(
                    np.float32),
                "labels": rng.integers(0, cfg.vocab_size, (B, S)).astype(
                    np.int32),
                "mask": mask,
            }
        return {"tokens": rng.integers(
            0, cfg.vocab_size, (B, S)).astype(np.int32)}

    def iterator(self, start_step: int = 0, prefetch: int = 2
                 ) -> Iterator[Dict[str, np.ndarray]]:
        """Background-thread prefetching iterator (overlaps host datagen
        with device compute)."""
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
