"""Pure-JAX optimizers (no optax dependency): SGD(+momentum), Adam, AdamW.

API mirrors the usual gradient-transform style:

    opt = adamw(lr=3e-4, weight_decay=0.1)
    state = opt.init(params)
    params, state = opt.step(params, grads, state, lr_scale=1.0)

Optimizer state is a pytree matching ``params`` — it shards the same way
(ZeRO-1: the train driver places it with the FSDP axes of the params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    step: Callable[..., Tuple[Any, Any]]
    name: str


def _tree_zeros_like(params, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def sgd(lr: float, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"count": jnp.zeros((), jnp.int32)}
        return {"mu": _tree_zeros_like(params),
                "count": jnp.zeros((), jnp.int32)}

    def step(params, grads, state, lr_scale=1.0):
        eta = lr * lr_scale
        if momentum == 0.0:
            new_p = jax.tree.map(lambda p, g: p - eta * g, params, grads)
            return new_p, {"count": state["count"] + 1}
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
        else:
            upd = mu
        new_p = jax.tree.map(lambda p, u: p - eta * u, params, upd)
        return new_p, {"mu": mu, "count": state["count"] + 1}

    return Optimizer(init, step, "sgd")


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, grad_clip: Optional[float] = None
          ) -> Optimizer:
    def init(params):
        return {
            "m": _tree_zeros_like(params, jnp.float32),
            "v": _tree_zeros_like(params, jnp.float32),
            "count": jnp.zeros((), jnp.int32),
        }

    def step(params, grads, state, lr_scale=1.0):
        if grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ +
                         (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        eta = lr * lr_scale

        def upd(p, m_, v_):
            u = (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - eta * u).astype(p.dtype)

        new_p = jax.tree.map(upd, params, m, v)
        return new_p, {"m": m, "v": v, "count": count}

    return Optimizer(init, step, "adamw")


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
         ) -> Optimizer:
    return adamw(lr, b1, b2, eps, weight_decay=0.0)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        # Warmup counts from step+1: schedule(0) > 0, so the very first
        # optimizer step is never a silent no-op that still consumes Adam's
        # bias-correction count.
        step = step.astype(jnp.float32)
        warm = jnp.minimum((step + 1.0) / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * cos
    return fn
