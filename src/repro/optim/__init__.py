from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adam, adamw, sgd, cosine_schedule, global_norm)
