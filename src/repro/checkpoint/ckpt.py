"""Checkpointing: atomic, versioned, async, integrity-checked, keep-last-k.

Layout:  <dir>/step_<N>/shard_<p>.npz + manifest.json

  * Leaves are flattened by tree path; each host process writes its own
    ``shard_<process_index>.npz`` (single-process here, but the API is
    multi-host shaped: restore concatenates by path).
  * Writes go to ``step_<N>.tmp`` then os.rename, with the payload files,
    the tmp directory, and the parent directory fsync'd around the rename
    — a crash (or power loss) mid-save never corrupts the latest
    checkpoint and a completed save is actually on the platter.
  * A background thread performs the device->host copy + write so training
    doesn't stall (async checkpointing); ``wait()`` joins before exit, and
    a failed background write raises from the *next* ``save()`` (which
    joins the writer first) as well as from ``wait()``.
  * manifest.json records step, per-leaf shapes/dtypes and a content hash;
    ``restore`` verifies the hash and falls back to the previous checkpoint
    on corruption.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _treedef_token(tree) -> str:
    return str(jax.tree_util.tree_structure(tree))


#: the spec-provenance sidecar written next to param / engine-state
#: checkpoints; binds the directory's contents to exactly one spec hash
SIDECAR = "spec.json"


def write_sidecar(directory: str, payload: Dict[str, Any]) -> str:
    """Atomically write the spec sidecar (tmp + rename, like the
    checkpoint itself); returns the sidecar path."""
    sidecar = os.path.join(directory, SIDECAR)
    tmp = sidecar + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, sidecar)
    return sidecar


def read_sidecar(directory: str) -> Dict[str, Any]:
    """The sidecar document, or FileNotFoundError when the directory was
    never checkpointed into (OSError / json.JSONDecodeError propagate for
    an unreadable one — callers turn them into actionable errors)."""
    sidecar = os.path.join(directory, SIDECAR)
    if not os.path.exists(sidecar):
        raise FileNotFoundError(
            f"no {SIDECAR} in checkpoint dir {directory!r}")
    with open(sidecar) as f:
        return json.load(f)


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directory fsync commits the
    rename itself — the atomic-save guarantee is only as durable as the
    parent directory's metadata)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.pidx = process_index
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        """Snapshot to host then write asynchronously.

        Joins any in-flight background write first, so an error from the
        *previous* async save surfaces here (callers that only ever call
        ``save()`` in a loop still see write failures promptly, not just
        at the final ``wait()``).
        """
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                self._write(step, host_state)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _write(self, step: int, host_state) -> None:
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_state)
        arrays = {f"a{i}": leaf for i, (_, leaf) in enumerate(flat)}
        np.savez(os.path.join(tmp, f"shard_{self.pidx}.npz"), **arrays)
        digest = hashlib.sha256()
        for _, leaf in flat:
            digest.update(np.ascontiguousarray(leaf).tobytes())
        manifest = {
            "step": step,
            "paths": [p for p, _ in flat],
            "shapes": [list(np.shape(l)) for _, l in flat],
            "dtypes": [str(np.asarray(l).dtype) for _, l in flat],
            "treedef": _treedef_token(host_state),
            "hash": digest.hexdigest(),
            "n_processes": 1,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            f.write(json.dumps(manifest))
            f.flush()
            os.fsync(f.fileno())
        # durability: payload files -> tmp dir entries -> rename -> parent
        # dir metadata.  Without the final directory fsync the rename can
        # vanish on power loss even though every file inside survived.
        _fsync_path(os.path.join(tmp, f"shard_{self.pidx}.npz"))
        _fsync_path(tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_path(self.dir)
        self._gc(current=step)

    def _gc(self, current: Optional[int] = None) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            if s == current:
                continue  # never collect the step this writer just renamed
            path = os.path.join(self.dir, f"step_{s:010d}")
            if os.path.exists(path + ".tmp"):
                continue  # another writer is mid-flight on this step
            shutil.rmtree(path, ignore_errors=True)

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int]:
        """Restore into the structure of ``like``; verifies integrity and
        falls back to older checkpoints on corruption."""
        self.wait()
        candidates = [step] if step is not None else self.all_steps()[::-1]
        for s in candidates:
            try:
                return self._load(like, s, shardings), s
            except Exception:
                continue
        raise FileNotFoundError(f"no restorable checkpoint in {self.dir}")

    def _load(self, like, step: int, shardings):
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, f"shard_{self.pidx}.npz"))
        leaves = [data[f"a{i}"] for i in range(len(manifest["paths"]))]
        digest = hashlib.sha256()
        for leaf in leaves:
            digest.update(np.ascontiguousarray(leaf).tobytes())
        if digest.hexdigest() != manifest["hash"]:
            raise IOError(f"checkpoint step {step} failed integrity check")
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                tree, shardings)
        return tree
