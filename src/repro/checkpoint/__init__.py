from repro.checkpoint.ckpt import (  # noqa: F401
    CheckpointManager,
    SIDECAR,
    read_sidecar,
    write_sidecar,
)
