"""rwkv6-3b (Finch) — attention-free RNN with data-dependent decay
[arXiv:2404.05892].

32L, d_model=2560 (40 heads of size 64), channel-mix d_ff=8960, vocab=65536.
"""
from repro.configs.base import ModelConfig, RWKVConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,            # d_model / head_size
        n_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab_size=65536,
        rwkv=RWKVConfig(head_size=64, decay_lora=64, mix_lora=32),
        microbatch=8,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        rwkv=RWKVConfig(head_size=16, decay_lora=8, mix_lora=8),
        attn_chunk=64,
    )
