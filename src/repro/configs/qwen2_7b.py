"""qwen2-7b — dense GQA LM with QKV bias [arXiv:2407.10671].

28L, d_model=3584, 28 heads (GQA kv=4, head_dim=128), d_ff=18944,
vocab=152064, QKV bias.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        family="dense",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        microbatch=8,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        qkv_bias=True,
        attn_chunk=64,
    )
