"""paligemma-3b — SigLIP + Gemma VLM backbone [arXiv:2407.07726].

The assigned entry specifies the TRANSFORMER BACKBONE only (18L gemma-2b,
d_model=2048, 8 heads MQA kv=1, head_dim=256, d_ff=16384, vocab=257216).  The
SigLIP vision tower is a STUB: ``input_specs()`` provides precomputed patch
embeddings (B, 256, d_model) which are concatenated in front of the text
embeddings (prefix-LM style).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,          # MQA
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        frontend="patch",
        n_frontend_tokens=256,  # 224px / 14 patch -> 16x16
        tie_embeddings=True,
        microbatch=8,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="paligemma-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        frontend="patch",
        n_frontend_tokens=16,
        tie_embeddings=True,
        attn_chunk=64,
    )
