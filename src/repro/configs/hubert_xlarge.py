"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].

48L, d_model=1280, 16 heads (kv=16, head_dim=80), d_ff=5120, vocab=504
(masked-prediction codebook).  The CNN waveform frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings (B, S, d_model).
Encoder-only => no decode shapes (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        causal=False,          # encoder-only
        frontend="frame",
        microbatch=8,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=64,
        causal=False,
        frontend="frame",
        attn_chunk=64,
    )
