"""Tiny dense causal LMs: the federated ``tiny_lm`` entry + example scales.

:func:`dense_lm` is the one place a plain dense-LM :class:`ModelConfig`
is assembled from a (d_model, n_layers) budget — the pretrain example and
any future driver size their models through it instead of hand-writing
configs.  ``config()``/``smoke()`` expose the CPU-sized variant the
federated model registry (``models/registry.py`` ``tiny_lm``) binds; it
is registered as arch id ``tiny-lm`` so ``--arch tiny-lm`` works in every
driver that resolves through ``configs/registry.py``.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig


def dense_lm(d_model: int, n_layers: int, vocab_size: int = None,
             **kw) -> ModelConfig:
    """A dense decoder sized from (d_model, n_layers); heads are d/64
    (head_dim 64) with GQA when 4 divides them, ff ~ 8/3 d rounded to 64."""
    heads = max(d_model // 64, 1)
    kv = 4 if heads % 4 == 0 else heads
    if vocab_size is None:
        vocab_size = 32000 if d_model >= 768 else 8192
    return ModelConfig(
        name=f"lm-{n_layers}x{d_model}", family="dense",
        n_layers=n_layers, d_model=d_model, n_heads=heads, n_kv_heads=kv,
        head_dim=64, d_ff=max(int(d_model * 8 / 3) // 64 * 64, 64),
        vocab_size=vocab_size, attn_chunk=256, **kw)


def config() -> ModelConfig:
    """The federated tiny LM: small enough that the vmapped per-client
    update stays CPU-cheap at simulation scale (remat off: the fused
    round step re-runs it per event, activations are tiny)."""
    return ModelConfig(
        name="tiny-lm", family="dense", n_layers=1, d_model=32,
        n_heads=2, n_kv_heads=2, head_dim=16, d_ff=96, vocab_size=64,
        attn_chunk=64, remat=False)


def long() -> ModelConfig:
    """The long-sequence federated tiny LM (arch id ``tiny-lm-long``):
    same weights-shape as ``tiny-lm`` but tuned for seq_len ~128, where
    the O(S^2) attention term dominates the step — this is the config the
    engine_lm flash-vs-reference bench rows run (benchmarks/run.py).
    ``attn_chunk=32`` keeps both backends on their chunked paths so the
    comparison is streaming-vs-streaming, not streaming-vs-materialized.
    """
    return config().replace(name="tiny-lm-long", attn_chunk=32)


def smoke() -> ModelConfig:
    return config()
