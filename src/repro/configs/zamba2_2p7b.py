"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

54 Mamba2 layers, d_model=2560, ssm_state=64; a single SHARED full-attention
(+gated-MLP) block (32 heads, kv=32, d_ff=10240) is applied every 6 backbone
layers with the SAME weights (Zamba2's parameter-sharing trick).  vocab=32000.
"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,          # 2560 / 32
        d_ff=10240,
        vocab_size=32000,
        attn_every=6,         # shared attention block cadence
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
        microbatch=8,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        attn_every=2,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32),
        attn_chunk=64,
    )
