"""minitron-8b — width-pruned Nemotron-4 dense LM [arXiv:2407.14679].

32L, d_model=4096, 32 heads (GQA kv=8, head_dim=128), d_ff=16384,
vocab=256000.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=256000,
        microbatch=8,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        attn_chunk=64,
    )
