"""Architecture registry: ``--arch <id>`` resolution for every driver."""
from __future__ import annotations

from types import SimpleNamespace
from typing import Callable, Dict, List

from repro.configs.base import ModelConfig
from repro.configs import (
    zamba2_2p7b,
    paligemma_3b,
    h2o_danube3_4b,
    qwen2_7b,
    minitron_8b,
    qwen1p5_110b,
    granite_moe_3b,
    deepseek_moe_16b,
    rwkv6_3b,
    hubert_xlarge,
    tiny_lm,
)

_MODULES = {
    "zamba2-2.7b": zamba2_2p7b,
    "paligemma-3b": paligemma_3b,
    "h2o-danube-3-4b": h2o_danube3_4b,
    "qwen2-7b": qwen2_7b,
    "minitron-8b": minitron_8b,
    "qwen1.5-110b": qwen1p5_110b,
    "granite-moe-3b-a800m": granite_moe_3b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "rwkv6-3b": rwkv6_3b,
    "hubert-xlarge": hubert_xlarge,
    # CPU-sized dense LM backing the federated ``tiny_lm`` model entry
    # (models/registry.py); also drivable directly: --arch tiny-lm
    "tiny-lm": tiny_lm,
    # long-sequence variant backing the ``tiny_lm_long`` federated entry
    # and the flash-vs-reference bench rows (benchmarks/run.py)
    "tiny-lm-long": SimpleNamespace(config=tiny_lm.long, smoke=tiny_lm.long),
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return _MODULES[arch].config()


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return _MODULES[arch].smoke()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
