from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, RWKVConfig, TrainConfig  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeConfig, applicable, smoke_shape  # noqa: F401
