"""granite-moe-3b-a800m — fine-grained MoE [hf:ibm-granite/granite-3.0-3b-a800m].

32L, d_model=1536, 24 heads (GQA kv=8, head_dim=64), vocab=49155 (padded to a
multiple of 256 for TP), MoE: 40 experts, top-8, expert d_ff=512.
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,  # per-expert
        vocab_size=49155,
        moe=MoEConfig(n_experts=40, top_k=8, expert_d_ff=512),
        tie_embeddings=True,
        microbatch=8,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab_size=128,
        moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=64),
        tie_embeddings=True,
        attn_chunk=64,
    )
