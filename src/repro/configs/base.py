"""Base model / run configuration for the repro framework.

Every architecture in ``src/repro/configs/`` instantiates :class:`ModelConfig`
(exact published hyper-parameters) plus a ``smoke()`` reduced variant used by
CPU tests. Input shapes live in :mod:`repro.configs.shapes`.

The config is a frozen dataclass so it can be closed over by jitted functions
safely (hashable, no accidental mutation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


#: valid ``ModelConfig.attention_backend`` / ``data.attention_backend``
#: values (one source of truth for config, spec validation, and docs)
ATTENTION_BACKENDS = ("auto", "flash", "reference")


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (GShard/Switch-style dense dispatch)."""

    n_experts: int
    top_k: int
    expert_d_ff: int
    n_shared_experts: int = 0
    shared_d_ff: int = 0           # d_ff of the always-on shared expert(s)
    capacity_factor: float = 1.25  # per-expert capacity = cf * top_k * S / E
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2
    # dtype of routing one-hots/cumsums/combine: bf16 is integer-exact up to
    # 256 == GROUP, so capacity math stays lossless while the
    # (n,g,G,E,C)-sized intermediates halve — a §Perf memory-term lever.
    route_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) sub-config."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64             # SSD head dim (P)
    chunk: int = 256               # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 (Finch) sub-config."""

    head_size: int = 64
    decay_lora: int = 64           # rank of the data-dependent decay LoRA
    mix_lora: int = 32             # rank of the token-shift mixing LoRA


@dataclass(frozen=True)
class ModelConfig:
    # identity -------------------------------------------------------------
    name: str = "unnamed"
    family: str = "dense"          # dense | moe | ssm | hybrid | audio | vlm

    # transformer core ------------------------------------------------------
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 512
    vocab_size: int = 256
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    causal: bool = True            # False => encoder-only (bidirectional)

    # sliding-window attention (None => full attention)
    swa_window: Optional[int] = None

    # hybrid (zamba2-style): a SHARED attention+MLP block applied every
    # ``attn_every`` backbone layers. 0 => no shared block.
    attn_every: int = 0

    # modality frontend stub: none | patch | frame.  When not "none",
    # input_specs() provides precomputed (B, S_front, d_model) embeddings.
    frontend: str = "none"
    n_frontend_tokens: int = 0     # e.g. image patches for the VLM

    # sub-configs ------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # attention backend -----------------------------------------------------
    #: "auto" | "flash" | "reference".  "flash" routes full-sequence
    #: attention through the kernel layer (repro.kernels.ops.attention:
    #: the Pallas flash kernel on TPU, the blocked-streaming jnp path
    #: elsewhere — causally clipped K/V, no (S, T) logits materialized).
    #: "reference" keeps the naive chunked softmax path (the bitwise
    #: parity oracle).  "auto" resolves by availability at trace time
    #: (models/attention.resolve_attention_backend): flash wherever the
    #: TP contract allows (tp == 1), reference otherwise.
    attention_backend: str = "auto"

    # training --------------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    microbatch: int = 0            # 0 => no gradient accumulation
    fsdp: bool = True              # shard weights/opt-state over the data axis
    scan_layers: bool = True
    attn_chunk: int = 1024         # query-chunk for memory-safe attention
    # Unroll inner seq-chunk scans (attention/WKV/SSD/loss). Used by the
    # roofline's per-layer costing so cost_analysis sees every chunk
    # (XLA counts while-loop bodies once).  Off for real compiles.
    unroll_scans: bool = False

    # capability flags -------------------------------------------------------
    @property
    def is_decoder(self) -> bool:
        return self.causal

    @property
    def sub_quadratic(self) -> bool:
        """True iff decode state does not grow quadratically with context and
        per-token decode cost/caches stay bounded (SSM / SWA / hybrid)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.swa_window is not None
            or self.rwkv is not None
        )

    # ---- TP-padding helpers (model axis of size ``tp``) ---------------------
    def padded_heads(self, tp: int) -> int:
        """Query heads padded so they divide the tensor-parallel axis."""
        return _round_up(self.n_heads, tp) if tp > 1 else self.n_heads

    def kv_sharded(self, tp: int) -> bool:
        """KV heads are shardable over the model axis iff divisible."""
        return tp > 1 and self.n_kv_heads % tp == 0

    def padded_vocab(self, tp: int) -> int:
        return _round_up(self.vocab_size, 256 if tp > 1 else 1)

    # ---- misc ---------------------------------------------------------------
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytical parameter count (true, un-padded config)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d
        if self.frontend != "none":
            emb += d * d  # frontend adapter stub projection
        per_layer = 0
        if self.family in ("dense", "vlm", "audio"):
            per_layer = self._attn_params() + self._dense_ffn_params() + 2 * d
        elif self.family == "moe":
            m = self.moe
            routed = m.n_experts * 3 * d * m.expert_d_ff
            shared = m.n_shared_experts * 3 * d * (m.shared_d_ff or m.expert_d_ff)
            router = d * m.n_experts
            per_layer = self._attn_params() + routed + shared + router + 2 * d
        elif self.family == "ssm":
            r = self.rwkv
            H = d // r.head_size
            tmix = 4 * d * d + d * d  # r,k,v,g projections + output
            tmix += 2 * d * r.decay_lora + 6 * d * r.mix_lora  # LoRAs
            tmix += H * r.head_size  # per-head `u` bonus
            cmix = 2 * d * self.d_ff  # rwkv channel-mix has 2 mats (k,v)
            per_layer = tmix + cmix + 2 * d
        elif self.family == "hybrid":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            mamba = (
                d * (2 * di + 2 * s.d_state * (di // s.head_dim) + nh) // 1
                + di * d          # out proj
                + s.d_conv * (di + 2 * s.d_state * nh) // 1
                + nh              # A_log, D
            )
            # simpler faithful estimate: in_proj (d -> 2*di + 2*n_groups*d_state + nh)
            zxbcdt = 2 * di + 2 * s.d_state + nh
            mamba = d * zxbcdt + di * d + s.d_conv * di + 2 * nh
            per_layer = mamba + 2 * d
        total = emb + L * per_layer
        if self.attn_every:
            total += self._attn_params() + self._dense_ffn_params() + 2 * self.d_model
        if not self.tie_embeddings:
            total += self.vocab_size * d
        return total

    def _attn_params(self) -> int:
        d = self.d_model
        q = d * self.n_heads * self.head_dim
        kv = 2 * d * self.n_kv_heads * self.head_dim
        o = self.n_heads * self.head_dim * d
        b = (self.n_heads + 2 * self.n_kv_heads) * self.head_dim if self.qkv_bias else 0
        return q + kv + o + b

    def _dense_ffn_params(self) -> int:
        # gated (SwiGLU-style) FFN: w_in, w_gate, w_out
        return 3 * self.d_model * self.d_ff

    def active_param_count(self) -> int:
        """Active parameters per token (MoE counts only routed top-k)."""
        if self.family != "moe":
            return self.param_count()
        d, L, m = self.d_model, self.n_layers, self.moe
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        routed = m.top_k * 3 * d * m.expert_d_ff
        shared = m.n_shared_experts * 3 * d * (m.shared_d_ff or m.expert_d_ff)
        per_layer = self._attn_params() + routed + shared + d * m.n_experts + 2 * d
        return emb + L * per_layer


@dataclass(frozen=True)
class TrainConfig:
    """Optimizer / schedule / fault-tolerance knobs for the training driver."""

    lr: float = 3e-4
    weight_decay: float = 0.1
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0

    # FedAT-at-scale knobs (cross-tier / cross-pod behaviour)
    fedat_enabled: bool = False
    fedat_sync_every: int = 1      # cross-tier aggregation cadence (steps)
    fedat_lambda: float = 0.4      # proximal constraint (paper lambda)
    fedat_compress_bits: int = 0   # 0 => fp32 cross-tier sync; 8/16 => quantized

    # checkpointing / fault tolerance
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_ckpts: int = 3
