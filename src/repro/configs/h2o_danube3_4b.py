"""h2o-danube-3-4b — llama+mistral-style dense LM with sliding-window attention
[arXiv:2401.16818].

24L, d_model=3840, 32 heads (GQA kv=8, head_dim=120), d_ff=10240, vocab=32000,
SWA window 4096 (mistral-style).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        head_dim=120,         # 3840 / 32
        d_ff=10240,
        vocab_size=32000,
        swa_window=4096,
        microbatch=8,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        swa_window=64,
        attn_chunk=64,
    )
