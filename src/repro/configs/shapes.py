"""Assigned input shapes.

Each LM architecture is exercised on up to four shapes:

=============  =========  ============  ====================================
shape id       seq_len    global_batch  step lowered
=============  =========  ============  ====================================
train_4k       4,096      256           ``train_step``
prefill_32k    32,768     32            ``serve_prefill``
decode_32k     32,768     128           ``serve_step`` (1 new token, KV cache)
long_500k      524,288    1             ``serve_step`` (sub-quadratic only)
=============  =========  ============  ====================================

``decode_*`` / ``long_*`` lower one-token decode against a cache of
``seq_len``; they are skipped for encoder-only models.  ``long_500k`` is
skipped for pure full-attention architectures (see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Whether (arch x shape) is a defined cell (DESIGN.md §Arch-applicability)."""
    if shape.kind == "decode" and not cfg.is_decoder:
        return False  # encoder-only: no autoregressive decode
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False  # pure full-attention: 500k decode cache unbounded
    return True


def smoke_shape(kind: str = "train") -> ShapeConfig:
    """Tiny shape for CPU smoke tests."""
    if kind == "train":
        return ShapeConfig("smoke_train", 128, 4, "train")
    if kind == "prefill":
        return ShapeConfig("smoke_prefill", 128, 2, "prefill")
    return ShapeConfig("smoke_decode", 128, 2, "decode")
