"""qwen1.5-110b — large dense GQA LM with QKV bias [hf:Qwen/Qwen1.5-110B].

80L, d_model=8192, 64 heads (GQA kv=8, head_dim=128), d_ff=49152,
vocab=152064, QKV bias.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=49152,
        vocab_size=152064,
        qkv_bias=True,
        microbatch=16,  # grad accumulation: 110B activations need microbatching
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        qkv_bias=True,
        attn_chunk=64,
    )
