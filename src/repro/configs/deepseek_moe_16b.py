"""deepseek-moe-16b — fine-grained MoE with shared experts [arXiv:2401.06066].

28L, d_model=2048, 16 heads (kv=16, head_dim=128), vocab=102400,
MoE: 64 routed experts top-6 + 2 always-on shared experts, expert d_ff=1408.
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,  # per-expert
        vocab_size=102400,
        moe=MoEConfig(
            n_experts=64,
            top_k=6,
            expert_d_ff=1408,
            n_shared_experts=2,
            shared_d_ff=1408,
        ),
        microbatch=8,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=64,
        vocab_size=128,
        moe=MoEConfig(
            n_experts=8, top_k=2, expert_d_ff=64, n_shared_experts=1, shared_d_ff=64
        ),
        attn_chunk=64,
    )
