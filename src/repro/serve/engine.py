"""Fixed-slot continuous-batching serve engine.

The design contract (DESIGN.md §Serving-plane):

  * **One trace per config.**  Exactly three jitted functions — prefill
    ``(B, P)``, decode ``(B,)``, slot-reset ``(cache, mask)`` — all shaped
    by :class:`ServeSpec`, never by the live request mix.  Admission,
    retirement, and handoff are host-side bookkeeping over those fixed
    shapes; ``trace_counts`` proves no silent retrace.
  * **Per-slot positions.**  RoPE is translation-equivariant
    mathematically but not bitwise, so a recycled slot restarts at
    position 0 with its own entry in the ``(B,)`` position vector while
    neighbours keep decoding (models/attention.py decode_attention).
  * **Cache-reset invariant.**  Before a slot is reused, its cache rows
    are reset to exactly the ``init_cache`` state (positions ``-1``,
    K/V ``0``), so a recycled slot is bitwise indistinguishable from a
    fresh one.
  * **Exact handoff.**  A request admitted mid-flight force-feeds its
    remaining prompt tokens through decode steps (logits discarded until
    the last prompt token); nothing of the prompt is dropped.  Batched
    prefill is only exact for attention-only families — recurrent state
    (ssm/hybrid) integrates padding, so those families always force-feed.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serve.spec import ServeSpec


@dataclasses.dataclass
class ServeRequest:
    """One generation request plus its measured lifecycle."""
    rid: int
    prompt: np.ndarray            # (len,) int32 token ids
    max_new: int
    #: open-loop arrival offset (seconds from engine start); 0 = already
    #: queued when the engine starts
    arrival: float = 0.0
    out: List[int] = dataclasses.field(default_factory=list)
    #: True when the max_len position budget ended generation before
    #: max_new tokens — distinguishable from a normally-finished request
    truncated: bool = False
    # lifecycle timestamps (seconds from engine start; -1 = never)
    t_admit: float = -1.0
    t_first: float = -1.0         # first *generated* token emitted
    t_done: float = -1.0

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class ServeEngine:
    """Continuous-batching decoder over a federated (or fresh) param tree.

    ``cfg`` is the bound :class:`ModelConfig` (e.g. ``loaded.config``
    from :mod:`repro.serve.loader`), ``params`` the LM-facade param tree.
    """

    def __init__(self, cfg, params, spec: ServeSpec, tp: int = 1):
        spec.validate()
        self.cfg = cfg
        self.spec = spec
        self.tp = tp
        self.params = params
        self.dtype = jnp.float32 if spec.dtype == "float32" else jnp.bfloat16
        B, T = spec.slots, spec.max_len
        self.is_transformer = cfg.family in lm.TRANSFORMER_FAMILIES
        #: physical cache rows per slot (SWA archs ring over the window)
        self.cache_rows = (min(T, cfg.swa_window) if cfg.swa_window else T)
        self.cache = lm.init_cache(cfg, B, T, tp, self.dtype)
        # host-side slot state
        self.slot_req: List[Optional[ServeRequest]] = [None] * B
        self.pending: List[Deque[int]] = [deque() for _ in range(B)]
        self.pos = np.zeros(B, np.int32)          # tokens consumed per slot
        self.next_tok = np.zeros(B, np.int32)     # last model output per slot
        #: jit trace counters — the one-trace-per-config contract;
        #: incremented by Python side effect at trace time only
        self.trace_counts: Dict[str, int] = {"prefill": 0, "decode": 0,
                                             "reset": 0}
        V = cfg.vocab_size

        def prefill_fn(p, toks, last_pos, c):
            self.trace_counts["prefill"] += 1
            logits, c = lm.serve_prefill(cfg, p, {"tokens": toks}, tp, c,
                                         last_pos=last_pos)
            nxt = jnp.argmax(logits[:, :V], axis=-1).astype(jnp.int32)
            return nxt, c

        def decode_fn(p, toks, pos, c):
            self.trace_counts["decode"] += 1
            logits, c = lm.serve_step(cfg, p, toks, pos, tp, c)
            nxt = jnp.argmax(logits[:, :V], axis=-1).astype(jnp.int32)
            return nxt, c

        axes = lm.cache_axes_tree(cfg, tp)

        def reset_fn(c, mask):
            # mask: (B,) bool — True resets that slot's rows to the
            # init_cache state (int leaves -> -1 i.e. "empty position",
            # float leaves -> 0, matching init_cache / init_state)
            self.trace_counts["reset"] += 1

            def reset_leaf(leaf, ax):
                i = ax.index("cache_batch")
                shape = [1] * leaf.ndim
                shape[i] = mask.shape[0]
                m = mask.reshape(shape)
                empty = (jnp.full_like(leaf, -1)
                         if jnp.issubdtype(leaf.dtype, jnp.integer)
                         else jnp.zeros_like(leaf))
                return jnp.where(m, empty, leaf)

            return jax.tree.map(reset_leaf, c, axes)

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)
        self._reset = jax.jit(reset_fn)

    # ------------------------------------------------------------------
    # slot bookkeeping (host side)
    # ------------------------------------------------------------------

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self, queue: Deque[ServeRequest], now: float) -> List[int]:
        """Move arrived requests into free slots; resets their cache rows.
        Returns the admitted slot indices."""
        admitted = []
        mask = np.zeros(self.spec.slots, bool)
        for i in self._free_slots():
            if not queue or queue[0].arrival > now:
                break
            r = queue.popleft()
            r.t_admit = now
            self.slot_req[i] = r
            self.pending[i] = deque(int(t) for t in np.asarray(r.prompt))
            self.pos[i] = 0
            self.next_tok[i] = 0
            mask[i] = True
            admitted.append(i)
        if admitted:
            self.cache = self._reset(self.cache, jnp.asarray(mask))
        return admitted

    def _retire(self, i: int, now: float, truncated: bool,
                done: List[ServeRequest]) -> None:
        r = self.slot_req[i]
        r.truncated = truncated
        r.t_done = now
        done.append(r)
        self.slot_req[i] = None
        self.pending[i].clear()

    # ------------------------------------------------------------------
    # batched prefill (attention-only families, fresh batches)
    # ------------------------------------------------------------------

    def _can_prefill(self, slots: List[int]) -> bool:
        """Batched prefill is used when *every* active slot was admitted
        this instant (no slot holds live decode state the (B, P) prefill
        trace would clobber) and every prompt fits the trace width."""
        if not self.is_transformer:
            return False
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if sorted(slots) != active:
            return False
        # a padded prefill wider than the physical cache would ring-evict
        # the *real* rows of a short prompt in favour of its padding
        if self.spec.prefill_len > self.cache_rows:
            return False
        return all(len(self.pending[i]) <= self.spec.prefill_len
                   for i in slots)

    def _prefill_wave(self, slots: List[int], now: float) -> None:
        B, P = self.spec.slots, self.spec.prefill_len
        toks = np.zeros((B, P), np.int32)
        last_pos = np.zeros(B, np.int32)
        for i in slots:
            prompt = list(self.pending[i])
            toks[i, :len(prompt)] = prompt        # left-aligned: exact
            last_pos[i] = len(prompt) - 1
            self.pending[i].clear()
        nxt, self.cache = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(last_pos),
            self.cache)
        nxt = np.asarray(nxt)
        for i in slots:
            r = self.slot_req[i]
            self.pos[i] = len(r.prompt)
            self.next_tok[i] = nxt[i]
            r.out.append(int(nxt[i]))
            r.t_first = now

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------

    def run(self, requests: List[ServeRequest],
            clock: Callable[[], float] = time.monotonic,
            ) -> List[ServeRequest]:
        """Serve ``requests`` (open loop: each becomes admissible at its
        ``arrival`` offset) to completion; returns them in finish order
        with lifecycle timestamps filled in."""
        t0 = clock()
        now = lambda: clock() - t0  # noqa: E731
        queue: Deque[ServeRequest] = deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))
        done: List[ServeRequest] = []
        B, T = self.spec.slots, self.spec.max_len

        while queue or any(r is not None for r in self.slot_req):
            t = now()
            admitted = self._admit(queue, t)
            if admitted and self._can_prefill(admitted):
                self._prefill_wave(admitted, now())
                # a prefilled request may already be done (max_new == 1)
                # or have spent its whole position budget on the prompt
                for i in admitted:
                    r = self.slot_req[i]
                    if r is None:
                        continue
                    if r.done:
                        self._retire(i, now(), truncated=False, done=done)
                    elif self.pos[i] >= T:
                        self._retire(i, now(), truncated=True, done=done)
                continue

            active = [i for i in range(B) if self.slot_req[i] is not None]
            if not active:
                # open loop: idle until the next arrival
                if queue:
                    wait = queue[0].arrival - now()
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
                continue

            # one decode step over all B slots (idle slots feed token 0
            # at their stale position; their output is discarded and
            # their rows are reset at the next admit)
            toks = np.array(self.next_tok, np.int32, copy=True)
            for i in active:
                if self.pending[i]:
                    toks[i] = self.pending[i].popleft()  # force-feed
            nxt, self.cache = self._decode(
                self.params, jnp.asarray(toks),
                jnp.asarray(self.pos, jnp.int32), self.cache)
            nxt = np.asarray(nxt)
            t = now()
            for i in active:
                self.pos[i] += 1
                self.next_tok[i] = nxt[i]
                r = self.slot_req[i]
                if self.pending[i]:
                    # consumed a prompt token, more remain: no output yet
                    if self.pos[i] >= T:
                        self._retire(i, t, truncated=True, done=done)
                    continue
                r.out.append(int(nxt[i]))
                if r.t_first < 0:
                    r.t_first = t
                if r.done:
                    self._retire(i, t, truncated=False, done=done)
                elif self.pos[i] >= T:
                    # position budget exhausted before max_new tokens
                    self._retire(i, t, truncated=True, done=done)
        return done
