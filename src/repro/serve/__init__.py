"""Serving plane: spec-hash-addressed checkpoints -> continuous batching.

The federated path produces params; this package serves them:

  * :mod:`repro.serve.loader`  — resolve a checkpoint directory by spec
    hash (the ``spec.json`` sidecar), rebuild the registered model from
    the spec, restore the exact step the sidecar names.
  * :mod:`repro.serve.engine`  — fixed-slot continuous-batching
    prefill/decode engine (one trace per config; per-slot positions;
    force-fed prompt handoff; cache-row reset on slot recycle).
  * :mod:`repro.serve.loadgen` — open-loop Poisson load generation and
    the p50/p95/p99 latency + throughput report.

See DESIGN.md §Serving-plane.
"""
from repro.serve.engine import ServeEngine, ServeRequest  # noqa: F401
from repro.serve.loader import (  # noqa: F401
    LoadedCheckpoint,
    load_checkpoint,
)
from repro.serve.loadgen import (  # noqa: F401
    make_requests,
    poisson_arrivals,
    report,
)
from repro.serve.spec import ServeSpec  # noqa: F401


def serve_from_checkpoint(checkpoint_dir, serve_spec, requests):
    """Load a spec-hash-verified checkpoint and serve ``requests``
    through a fresh engine; returns ``(loaded, done_requests)``."""
    loaded = load_checkpoint(checkpoint_dir)
    eng = ServeEngine(loaded.config, loaded.lm_params, serve_spec)
    return loaded, eng.run(requests)
