"""Open-loop load generation + latency/throughput reporting.

Open loop means arrivals are scheduled ahead of time from a Poisson
process (exponential inter-arrival gaps at ``rate`` req/s) and do *not*
wait for the server — the standard way to measure latency under load
without the coordinated-omission bias of closed-loop clients.  Arrivals
and prompts are deterministic in ``seed``, so a bench row is reproducible
run to run (only the measured wall-clock timings vary).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.serve.engine import ServeRequest


def poisson_arrivals(n: int, rate: float, seed: int) -> np.ndarray:
    """(n,) cumulative arrival offsets (seconds) for a Poisson process
    at ``rate`` req/s; ``rate <= 0`` means all arrive at t=0 (a closed
    burst — the max-pressure load level)."""
    if rate <= 0:
        return np.zeros(n)
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def make_requests(n: int, rate: float, prompt_len: int, max_new: int,
                  vocab_size: int, seed: int) -> List[ServeRequest]:
    """``n`` requests with Poisson arrivals and random prompts of length
    4..prompt_len (deterministic in ``seed``)."""
    rng = np.random.default_rng(seed)
    arrivals = poisson_arrivals(n, rate, seed)
    out = []
    for i in range(n):
        plen = int(rng.integers(4, prompt_len + 1))
        prompt = rng.integers(0, vocab_size, plen).astype(np.int32)
        out.append(ServeRequest(rid=i, prompt=prompt, max_new=max_new,
                                arrival=float(arrivals[i])))
    return out


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def report(done: List[ServeRequest]) -> Dict[str, float]:
    """p50/p95/p99 request latency + TTFT + queueing, and throughput.

    * latency      — arrival -> last token (the user-visible number)
    * ttft         — arrival -> first generated token
    * queueing     — arrival -> slot admission (load-level signal)
    * tok_per_s    — generated tokens / makespan (engine start -> last
                     completion), the serving-throughput headline
    """
    lat = [r.t_done - r.arrival for r in done if r.t_done >= 0]
    ttft = [r.t_first - r.arrival for r in done if r.t_first >= 0]
    queue = [r.t_admit - r.arrival for r in done if r.t_admit >= 0]
    toks = sum(len(r.out) for r in done)
    makespan = max((r.t_done for r in done if r.t_done >= 0), default=0.0)
    return {
        "requests": len(done),
        "truncated": sum(1 for r in done if r.truncated),
        "tokens": toks,
        "makespan_s": makespan,
        "tok_per_s": toks / makespan if makespan > 0 else float("nan"),
        "latency_p50_s": _pct(lat, 50),
        "latency_p95_s": _pct(lat, 95),
        "latency_p99_s": _pct(lat, 99),
        "ttft_p50_s": _pct(ttft, 50),
        "ttft_p95_s": _pct(ttft, 95),
        "queueing_p50_s": _pct(queue, 50),
        "queueing_p95_s": _pct(queue, 95),
    }
