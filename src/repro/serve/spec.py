"""ServeSpec: the declarative serving-side configuration.

Deliberately *not* a section of :class:`repro.api.spec.ExperimentSpec`:
the experiment spec hashes training provenance, and how a checkpoint is
later served (slot count, cache length) must not change which checkpoint
it resolves to.  The serve spec therefore lives next to the engine and
is validated the same way (fail early, name the fix).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

from repro.api.spec import SpecError, _strict_fields


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """How the engine batches and bounds one serving session."""
    #: fixed decode-batch width: every trace is shaped (slots, ...) so
    #: admission/retirement never retraces
    slots: int = 4
    #: absolute-position budget per request (cache length for full-cache
    #: archs; SWA archs ring over min(max_len, window)).  A request whose
    #: prompt + generation would cross this is retired with
    #: ``truncated=True``.
    max_len: int = 64
    #: padded prompt length of the batched prefill trace; prompts longer
    #: than this are force-fed token-by-token through decode instead
    prefill_len: int = 16
    #: per-request decode budget when the request doesn't carry its own
    max_new: int = 16
    #: engine rng seed (slot-independent; generation itself is greedy
    #: argmax, so this only seeds synthetic prompts in the drivers)
    seed: int = 0
    #: compute/cache dtype: "float32" | "bfloat16"
    dtype: str = "float32"

    def validate(self) -> "ServeSpec":
        if self.slots < 1:
            raise SpecError(f"serve.slots must be >= 1, got {self.slots}")
        if self.max_len < 2:
            raise SpecError(
                f"serve.max_len must be >= 2 (one prompt token + one "
                f"generated token), got {self.max_len}")
        if not (0 < self.prefill_len <= self.max_len):
            raise SpecError(
                f"serve.prefill_len must be in [1, max_len={self.max_len}]"
                f", got {self.prefill_len} — the prefill trace writes "
                f"cache rows 0..prefill_len-1")
        if self.max_new < 1:
            raise SpecError(f"serve.max_new must be >= 1, got "
                            f"{self.max_new}")
        if self.dtype not in ("float32", "bfloat16"):
            raise SpecError(f"serve.dtype must be float32|bfloat16, got "
                            f"{self.dtype!r}")
        return self

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ServeSpec":
        return cls(**_strict_fields(cls, dict(d), "serve")).validate()
