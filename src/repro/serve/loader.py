"""Spec-hash-addressed checkpoint loading for the serving plane.

A checkpoint directory written by ``Run.run(checkpoint_dir=...)`` (or
the CLI's ``--checkpoint-dir``) carries a ``spec.json`` sidecar binding
its params to exactly one :class:`ExperimentSpec` hash and one step.
:func:`load_checkpoint` resolves that binding end to end:

  sidecar -> ExperimentSpec.from_dict -> hash verify -> registry model
          -> CheckpointManager.restore(step=<sidecar step>)

Every failure mode is an actionable :class:`SpecError` — a serving
process must never come up on the wrong weights silently.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional

import jax

from repro import checkpoint as ckpt
from repro.api.spec import ExperimentSpec, SpecError
from repro.models import registry as model_registry


@dataclasses.dataclass(frozen=True)
class LoadedCheckpoint:
    """A federated checkpoint resolved into a servable model."""
    #: the spec that trained these params (rebuilt from the sidecar)
    spec: ExperimentSpec
    #: its 12-hex provenance hash (== sidecar's, verified)
    spec_hash: str
    #: the training step the params belong to
    step: int
    #: the restored params pytree, exactly as checkpointed
    params: Any
    #: the registry model bound to the spec's DataDims
    model: model_registry.FLModel

    @property
    def config(self):
        """The bound ModelConfig the engine rebuilds prefill/decode
        from (never ``None`` — the loader refuses non-servable models)."""
        return self.model.config

    @property
    def lm_params(self):
        """The LM-facade params subtree (federated checkpoints store
        ``{"params": <lm tree>}``; restore unwraps that already)."""
        return self.params


def spec_hash_of(doc: dict) -> str:
    """Hash of a spec *document* (dict) via a from_dict round-trip — the
    only hash that can be compared against a live spec's ``.hash()``
    (raw-dict hashing would miss migrations and defaults)."""
    return ExperimentSpec.from_dict(dict(doc)).hash()


def load_checkpoint(directory: str,
                    expect_spec: Optional[ExperimentSpec] = None,
                    ) -> LoadedCheckpoint:
    """Resolve ``directory`` into a :class:`LoadedCheckpoint`.

    ``expect_spec`` pins the load to one spec: a sidecar whose hash
    differs is refused (the serve-a-specific-run contract).  Without it,
    the sidecar's own embedded spec document is trusted — but still
    re-hashed after the from_dict round trip, so a hand-edited or
    version-drifted sidecar cannot smuggle mismatched provenance.
    """
    try:
        saved = ckpt.read_sidecar(directory)
    except FileNotFoundError:
        raise SpecError(
            f"no {ckpt.SIDECAR} in checkpoint dir {directory!r}; serving "
            f"needs a checkpoint written by Run.run(checkpoint_dir=...) "
            f"or the CLI's --checkpoint-dir")
    except (OSError, json.JSONDecodeError) as e:
        raise SpecError(f"unreadable {ckpt.SIDECAR} in checkpoint dir "
                        f"{directory!r}: {e}") from e

    doc = saved.get("spec")
    if not isinstance(doc, dict):
        raise SpecError(
            f"{ckpt.SIDECAR} in {directory!r} has no embedded spec "
            f"document; re-checkpoint with a current repro build")
    try:
        spec = ExperimentSpec.from_dict(dict(doc)).validate()
    except SpecError as e:
        raise SpecError(f"checkpoint {directory!r} sidecar spec does not "
                        f"parse: {e}") from e
    if spec.hash() != saved.get("spec_hash"):
        raise SpecError(
            f"checkpoint {directory!r} sidecar is self-inconsistent: "
            f"embedded spec hashes to {spec.hash()} but the sidecar "
            f"claims {saved.get('spec_hash')} — the sidecar was edited "
            f"or written by an incompatible spec version; re-checkpoint")
    if expect_spec is not None and expect_spec.hash() != spec.hash():
        raise SpecError(
            f"checkpoint {directory!r} was written by spec {spec.hash()} "
            f"but serving was asked for spec {expect_spec.hash()}; point "
            f"at a checkpoint of the expected spec, or drop expect_spec "
            f"to serve what the directory actually holds")

    d = spec.data
    model = model_registry.build_model(d.model, model_registry.DataDims(
        n_classes=d.n_classes, image_hw=d.image_hw,
        n_features=d.n_features, vocab_size=d.vocab_size,
        seq_len=d.seq_len, attention_backend=d.attention_backend))
    if model.config is None:
        servable = [n for n in model_registry.registered_models()
                    if model_registry.MODELS[n](
                        model_registry.DataDims()).config is not None]
        raise SpecError(
            f"model {d.model!r} has no decode path (FLModel.config is "
            f"None) — only LM-facade models are servable; servable "
            f"models: {servable}")

    like = {"params": jax.eval_shape(model.init_params,
                                     jax.random.PRNGKey(0))}
    try:
        # the exact sidecar step — never "latest", which in a reused
        # directory could be another spec's params
        state, step = ckpt.CheckpointManager(directory).restore(
            like=like, step=saved.get("step"))
    except FileNotFoundError as e:
        raise SpecError(
            f"checkpoint dir {directory!r} has a {ckpt.SIDECAR} but no "
            f"restorable step {saved.get('step')}: {e}") from e
    return LoadedCheckpoint(spec=spec, spec_hash=spec.hash(), step=step,
                            params=state["params"], model=model)
