"""Partitioned-HLO analysis: collective inventory + wire-byte estimates.

Parses ``compiled.as_text()`` (the *post-SPMD* module, so shapes are
per-device) and estimates bytes moved over ICI per device:

    all-gather       (n-1)/n * result_bytes
    all-reduce       2 (n-1)/n * result_bytes     (ring: RS + AG)
    reduce-scatter   (n-1)/n * operand_bytes ~ result*(n-1)
    all-to-all       (n-1)/n * result_bytes
    collective-permute   result_bytes

``n`` is the replica-group size parsed from the op's replica_groups.
Collectives inside while-loop bodies execute once per iteration but appear
once in the text — the roofline therefore composes per-layer unrolled
lowerings (benchmarks/roofline.py) instead of trusting a whole-graph count;
this module additionally reports which computations the ops live in so that
composition can sanity-check itself.
"""
from __future__ import annotations

import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"(?P<shape>[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")

_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dtype, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _tuple_shapes(line: str) -> List[str]:
    """Shapes for ops returning tuples: '(f32[..], s8[..]) all-gather(...)'"""
    m = re.match(r"\s*%?\S+\s*=\s*\(([^)]*)\)\s*(all-gather|all-to-all)", line)
    if not m:
        return []
    return re.findall(r"[a-z0-9]+\[[0-9,]*\]", m.group(1))


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1)
        return len([x for x in first.split(",") if x.strip() != ""])
    return 2


def iter_collectives(hlo_text: str):
    for line in hlo_text.splitlines():
        if "-start" in line and "-done" not in line:
            pass  # async start carries the shape; done returns alias
        m = re.search(
            r"=\s*(?P<full>\(?[^=]*?)\b"
            r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start)?\(", line)
        if not m:
            continue
        op = m.group("op")
        shapes = _tuple_shapes(line)
        if not shapes:
            sm = re.search(r"=\s*([a-z0-9]+\[[0-9,]*\])", line)
            shapes = [sm.group(1)] if sm else []
        nbytes = sum(_shape_bytes(s) for s in shapes)
        yield op, nbytes, _group_size(line), line


def count_collectives(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for op, _, _, _ in iter_collectives(hlo_text):
        out[op] = out.get(op, 0) + 1
    return out


def collective_bytes(hlo_text: str) -> float:
    """Estimated per-device ICI bytes for one execution of the top-level
    computation (while-loop bodies counted once — see module docstring)."""
    total = 0.0
    seen_done = set()
    for op, nbytes, n, line in iter_collectives(hlo_text):
        if "-done" in line:
            continue
        frac = (n - 1) / max(n, 1)
        if op == "all-reduce":
            total += 2 * frac * nbytes
        elif op == "collective-permute":
            total += nbytes
        else:  # all-gather / reduce-scatter / all-to-all
            total += frac * nbytes
    return total
