"""Logical-axis sharding layer (MaxText-style).

Model code annotates parameters and activations with *logical* axis names;
a rule table maps logical names to physical mesh axes.  On a single device
(smoke tests) everything resolves to fully-replicated and the annotations
are no-ops, so the same model code runs on 1 CPU device and on the 512-chip
production mesh.

Physical mesh axes (see :mod:`repro.launch.mesh`):
  * ``pod``   — FedAT tier axis (multi-pod mesh only)
  * ``data``  — intra-tier data parallelism + FSDP weight sharding,
                and the per-round *client* axis of the fused round step
                (core/executor.py shards ``clients_per_round`` over it)
  * ``model`` — tensor parallelism (heads / mlp / vocab / experts)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

# Logical-name -> physical mesh axis (or tuple of axes).
DEFAULT_RULES: Dict[str, Axis] = {
    # federated round execution (core/executor.py / core/simulation.py)
    "clients": "data",          # per-round client fan-out + resident stacks
    "tiers": "pod",             # tier-model stack leading dim (optional)
    # activations
    "batch": ("pod", "data"),   # global batch over pods (tiers) x data
    "seq": None,                # activation sequence dim: replicated
    "embed": None,              # activation d_model dim: replicated
    # parameters
    "fsdp": "data",             # ZeRO-3 weight dim (usually the in-feature dim)
    "tp": "model",              # tensor-parallel dim (heads*hd / d_ff / vocab)
    "experts": "model",         # expert parallelism (deepseek-style EP)
    "layers": None,             # stacked-layer leading dim
    "none": None,
    # caches
    "kv_seq": "model",          # seq-sharded KV cache (non-divisible kv heads)
    "kv_heads": "model",        # head-sharded KV cache
    "cache_batch": ("pod", "data"),
}

_local = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_local, "mesh", None)


def current_rules() -> Dict[str, Axis]:
    return getattr(_local, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]] = None):
    """Install ``mesh`` (+ optional rule overrides) for model tracing."""
    prev = (current_mesh(), current_rules())
    _local.mesh = mesh
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _local.rules = merged
    try:
        yield
    finally:
        _local.mesh, _local.rules = prev


def _resolve(axes: Sequence[Optional[str]], mesh: Mesh, rules: Dict[str, Axis]) -> P:
    phys = []
    used: set = set()
    for name in axes:
        if name is None:
            phys.append(None)
            continue
        ax = rules.get(name)
        if ax is None:
            phys.append(None)
            continue
        # drop axes not present in this mesh (e.g. "pod" on the single-pod mesh)
        if isinstance(ax, tuple):
            ax = tuple(a for a in ax if a in mesh.shape and a not in used)
            # unwrap 1-tuples: P(("data",)) and P("data") denote the same
            # partitioning but only compare equal on newer jax
            ax = ax[0] if len(ax) == 1 else (ax if ax else None)
        elif ax not in mesh.shape or ax in used:
            ax = None
        if ax is not None:
            used.update(ax if isinstance(ax, tuple) else (ax,))
        phys.append(ax)
    return P(*phys)


def logical_sharding(axes: Sequence[Optional[str]],
                     mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    """NamedSharding for logical ``axes`` under the current (or given) mesh."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, _resolve(axes, mesh, current_rules()))


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` by logical axes; no-op without a mesh.

    Inside ``shard_map`` bodies the ambient *abstract* mesh (which marks the
    manual axes) must be used, otherwise XLA rejects the mixed-mesh program;
    the rule tables there must avoid manual axes (see core/steps.py
    INNER_RULES).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _resolve(axes, mesh, current_rules())
    # jax >= 0.5 tracks an ambient abstract mesh inside shard_map bodies;
    # on older versions the concrete mesh is always the right target.
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    am = get_am() if get_am is not None else None
    if am is not None and not am.empty and set(am.axis_names) == set(
            mesh.axis_names):
        return jax.lax.with_sharding_constraint(x, NamedSharding(am, spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(axes_tree, mesh: Optional[Mesh] = None):
    """Map a pytree of logical-axes tuples to NamedShardings (or None)."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return jax.tree.map(lambda _: None, axes_tree,
                            is_leaf=lambda l: isinstance(l, tuple))
    return jax.tree.map(lambda ax: logical_sharding(ax, mesh), axes_tree,
                        is_leaf=lambda l: isinstance(l, tuple) and all(
                            a is None or isinstance(a, str) for a in l))


def mesh_axis_size(name: str) -> int:
    """Size of a physical mesh axis under the thread-local current mesh
    (1 if absent).  Note for mesh-carrying objects (``SimEnv``,
    ``RoundExecutor``): size axes from your *own* mesh directly — this
    helper reads the ambient mesh, which is wrong for a no-mesh
    environment built inside a ``use_mesh()`` context."""
    mesh = current_mesh()
    if mesh is None or name not in mesh.shape:
        return 1
    return mesh.shape[name]


def tp_size() -> int:
    """Tensor-parallel degree implied by the current mesh ('model' axis)."""
    return mesh_axis_size("model")
