"""Datacenter-scale straggler mitigation = the paper's tiering, applied to
pods/workers instead of phones.

The profiler collects per-worker step latencies; ``build_tier_map`` feeds
them to core.tiering; ``sync_plan`` decides, per FedAT, which workers train
synchronously (same tier <=> comparable speed) and which pairs only
exchange compressed model deltas asynchronously (cross-tier).  This is the
component that turns "one slow pod stalls the world" (sync DP) into "one
slow pod becomes a slow *tier*" (FedAT).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core import tiering


@dataclasses.dataclass
class WorkerProfile:
    worker_id: int
    step_times: List[float] = dataclasses.field(default_factory=list)

    def observe(self, dt: float, window: int = 128) -> None:
        self.step_times.append(dt)
        if len(self.step_times) > window:
            self.step_times.pop(0)

    @property
    def latency(self) -> float:
        return float(np.median(self.step_times)) if self.step_times else 0.0


class FleetProfiler:
    def __init__(self, n_workers: int):
        self.workers = [WorkerProfile(i) for i in range(n_workers)]

    def observe(self, worker_id: int, dt: float) -> None:
        self.workers[worker_id].observe(dt)

    def latencies(self) -> np.ndarray:
        return np.array([w.latency for w in self.workers])

    def build_tier_map(self, n_tiers: int) -> tiering.TierMap:
        return tiering.assign_tiers(self.latencies(), n_tiers)


def sync_plan(tm: tiering.TierMap) -> Dict[str, object]:
    """For each tier: members train sync-DP; tiers exchange async.

    Returns the expected *relative* update rates (1/latency, normalized to
    the fastest tier) — the deployment-side estimate of the T_tier counters
    that drive Eq. 3 weights before real counts accumulate.
    """
    rates = []
    for ids in tm.members:
        lat = float(np.mean(tm.latencies[ids]))
        rates.append(1.0 / max(lat, 1e-9))
    rates = np.asarray(rates)
    rates = rates / rates.max()
    return {"tiers": [list(map(int, ids)) for ids in tm.members],
            "relative_rates": rates.tolist()}
