"""Elastic scaling: reshard training state when the device pool changes.

When a pod (tier) is lost or regained, FedAT keeps training: the tier map
shrinks/grows and the cross-tier weights renormalize (Eq. 3 is defined for
any M).  This module handles the mechanical part — moving a state pytree
onto a *new* mesh:

  * ``reshard(tree, new_shardings)``: device_put every leaf to its sharding
    on the new mesh (jax moves/reshuffles data as needed);
  * ``shrink_pods / grow_pods``: adjust the pod-stacked leading dim of a
    multi-pod FedAT state (dropping a tier keeps the survivors' models;
    adding a tier bootstraps the newcomer from the Eq. 3 global model);
  * update-count bookkeeping so aggregation weights stay consistent.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation


def reshard(tree: Any, new_shardings: Any) -> Any:
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        tree, new_shardings)


def shrink_pods(state: dict, keep: list) -> dict:
    """Drop lost tiers. ``keep``: surviving pod indices (e.g. [0, 2, 3])."""
    idx = jnp.asarray(keep)

    def take(x):
        return jnp.take(x, idx, axis=0)

    return {
        "params": jax.tree.map(take, state["params"]),
        "opt": jax.tree.map(take, state["opt"]),
        "step": take(state["step"]),
        "counts": take(state["counts"]),
    }


def grow_pods(state: dict, n_new: int) -> dict:
    """Add tiers: newcomers start from the current Eq. 3 global model with
    zero update count (they are 'slowest' until they catch up)."""
    w_global = aggregation.global_model(state["params"], state["counts"])
    opt0 = jax.tree.map(lambda x: jnp.zeros_like(x[:1]), state["opt"])

    def extend(stacked, new_single):
        rep = jnp.broadcast_to(new_single[None],
                               (n_new,) + new_single.shape)
        return jnp.concatenate([stacked, rep.astype(stacked.dtype)], axis=0)

    params = jax.tree.map(extend, state["params"], w_global)
    opt = jax.tree.map(
        lambda s, z: jnp.concatenate(
            [s] + [z.astype(s.dtype)] * n_new, axis=0),
        state["opt"], opt0)
    step = jnp.concatenate(
        [state["step"], jnp.full((n_new,), int(jnp.max(state["step"])),
                                 state["step"].dtype)])
    counts = jnp.concatenate(
        [state["counts"], jnp.zeros((n_new,), state["counts"].dtype)])
    return {"params": params, "opt": opt, "step": step, "counts": counts}


def masked_cross_weights(counts: np.ndarray,
                         alive: np.ndarray) -> np.ndarray:
    """Eq. 3 cross-tier weights renormalized over the surviving M' tiers.

    A blacked-out tier gets weight exactly 0; the survivors' weights are
    the paper's reversed-update-count weights computed *as if only they
    existed* (compress → Eq. 3 → scatter back), so they sum to 1 over M'.
    Host-side f32, same eager-weight discipline as
    :func:`~repro.core.aggregation.cross_tier_weights_host`.
    """
    alive = np.asarray(alive, bool)
    w = np.zeros(len(alive), np.float32)
    if alive.any():
        w[alive] = aggregation.cross_tier_weights_host(
            np.asarray(counts)[alive])
    return w


def bootstrap_tier(tier_models: Any, w_global: Any, m: int) -> Any:
    """A returning (post-blackout) tier restarts from the current global
    model: overwrite slot ``m`` of the (M, ...)-stacked tier models with
    ``w_global`` — the elastic 'grow' move applied in place on the
    fixed-M stack the engine strategies carry."""
    return jax.tree.map(
        lambda s, g: s.at[m].set(g.astype(s.dtype)), tier_models, w_global)
