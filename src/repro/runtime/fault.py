"""Fault tolerance: guarded step execution, straggler detection, retries.

At thousands of nodes, *something* is always failing.  The runtime wraps the
train step with:

  * checkpoint/restart — on step failure the state is restored from the
    last good checkpoint and training resumes (bounded retries, exponential
    backoff between attempts);
  * straggler detection — an EWMA of step latency; steps slower than
    ``threshold x`` the running median are flagged, and the per-worker
    slow-counts feed the FedAT tiering module (pods that persistently lag
    get re-tiered instead of stalling the sync group: the paper's insight
    applied at datacenter scale);
  * simulated failure injection for tests (``inject_failure_rate``).

This wrapper guards the *datacenter trainer* loop (launch/train.py).  The
simulation engine's fault story lives in core/faults.py instead: there,
faults are spec-driven and deterministic (churn windows, tier blackouts,
poisoned uplinks, bitwise crash-resume), because the engine's contract is
a reproducible trajectory — retry/backoff wall-clock machinery like this
has no place inside it.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.ckpt import CheckpointManager

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class StragglerStats:
    window: int = 64
    threshold: float = 2.0
    times: List[float] = dataclasses.field(default_factory=list)
    flags: int = 0

    def observe(self, dt: float) -> bool:
        """Record a step latency; returns True if it's a straggler step."""
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = float(np.median(self.times))
        slow = len(self.times) >= 8 and dt > self.threshold * med
        if slow:
            self.flags += 1
        return slow

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


class GuardedRunner:
    """Run (state, batch) -> (state, metrics) steps with restart-on-failure."""

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 ckpt_every: int = 50, max_retries: int = 3,
                 inject_failure_rate: float = 0.0, seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep,
                 clock: Callable[[], float] = time.perf_counter):
        """``sleep``/``clock`` are injectable so tests can drive the
        backoff and straggler timing deterministically without real
        wall-clock waits."""
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.inject = inject_failure_rate
        self.rng = np.random.default_rng(seed)
        self._sleep = sleep
        self._clock = clock
        self.straggler = StragglerStats()
        self.stats: Dict[str, int] = {"failures": 0, "restores": 0,
                                      "steps": 0, "straggler_steps": 0}

    def run(self, state: Any, batches, n_steps: int, start_step: int = 0,
            on_metrics: Optional[Callable] = None) -> Any:
        step = start_step
        it = iter(batches)
        while step < n_steps:
            batch = next(it)
            retries = 0
            while True:
                try:
                    if self.inject and self.rng.random() < self.inject:
                        raise RuntimeError("injected node failure")
                    t0 = self._clock()
                    state, metrics = self.step_fn(state, batch)
                    dt = self._clock() - t0
                    if self.straggler.observe(dt):
                        self.stats["straggler_steps"] += 1
                        log.warning("straggler step %d: %.3fs (median %.3fs)",
                                    step, dt, self.straggler.median)
                    break
                except Exception as e:  # noqa: BLE001 — node-failure path
                    self.stats["failures"] += 1
                    retries += 1
                    if retries > self.max_retries:
                        raise
                    log.warning("step %d failed (%s); restoring (retry %d)",
                                step, e, retries)
                    self._sleep(min(0.05 * 2 ** retries, 1.0))
                    try:
                        state, restored = self.ckpt.restore(state)
                        step = restored
                        self.stats["restores"] += 1
                    except FileNotFoundError:
                        pass  # no checkpoint yet: retry from current state
            step += 1
            self.stats["steps"] += 1
            if on_metrics:
                on_metrics(step, metrics)
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, state)
        self.ckpt.save(step, state, blocking=True)
        return state, step
