"""Codec-agnostic transport layer for the FL links (DESIGN.md §Transport).

One :class:`Codec` interface unifies the three faces every lossy link has:

  * ``lossy(params)``    — the in-graph quantize-dequantize step that models
    the link's effect on learning dynamics inside a jitted train path;
  * ``marshal/unmarshal`` — the actual wire message (what would be sent);
  * ``payload_bytes``     — wire-size accounting for the byte metrics.

Registered codecs:

  ``none``         identity links, raw f32 accounting.
  ``polyline``     the paper's §4.3 Encoded Polyline Algorithm codec
                   (``polyline:<p>`` selects the precision, default 4).
  ``quantize8``    blockwise fixed-point int8 quantization — the TPU-native
  ``quantize16``   polyline analogue (DESIGN.md §Hardware-adaptation).  The
                   lossy step runs the Pallas kernel in
                   kernels/polyline_codec.py (interpret mode on CPU).

``measure_ratio`` estimates wire/raw bytes on a size-capped parameter
sample so byte accounting stays cheap at scale (see the note on the
accounting approximation below).
"""
from __future__ import annotations

import abc
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import polyline, quantize

#: default element cap for sampled wire-ratio measurement.  Accounting
#: approximation: the ratio is measured on a per-leaf-proportional prefix
#: sample of at most this many elements and applied to the full model's
#: byte count.  Polyline payload length depends only on the local value
#: distribution (delta magnitudes), which the sample preserves; models
#: smaller than the cap are measured exactly.
RATIO_SAMPLE_ELEMS = 65536


def _sample_tree(params: Any, max_elems: Optional[int]) -> List[np.ndarray]:
    """Per-leaf-proportional flat prefix sample of a pytree (a list of 1-D
    arrays is itself a pytree, so codecs can marshal it directly)."""
    leaves = [np.asarray(l).reshape(-1) for l in jax.tree.leaves(params)]
    total = sum(l.size for l in leaves)
    if max_elems is None or total <= max_elems:
        return leaves
    frac = max_elems / total
    return [l[:max(1, int(l.size * frac))] for l in leaves]


class Codec(abc.ABC):
    """A lossy (or identity) link codec; see module docstring."""

    name: str = "codec"
    #: whether ``lossy`` is jit-composable (pure jax ops / Pallas), i.e.
    #: can run inside the fused round step (core/executor.py).  All
    #: registered codecs are in-graph: ``none`` is the identity,
    #: ``polyline`` rounds with jnp ops, ``quantize*`` runs the Pallas
    #: kernel (interpret mode on CPU).  A future host-side codec (e.g.
    #: one marshalling through Python bytes) must set this False and will
    #: be rejected by the fused step with a clear error.
    in_graph: bool = True

    def lossy(self, params: Any) -> Any:
        """In-graph encode->decode roundtrip (models the link's loss)."""
        return params

    @abc.abstractmethod
    def marshal(self, params: Any) -> Dict[str, Any]:
        """Pytree -> wire message."""

    @abc.abstractmethod
    def unmarshal(self, msg: Dict[str, Any]) -> Any:
        """Wire message -> pytree."""

    @abc.abstractmethod
    def payload_bytes(self, msg: Dict[str, Any]) -> int:
        """Wire size of a marshalled message."""

    def fixed_overhead_bytes(self, msg: Dict[str, Any]) -> int:
        """Per-leaf fixed wire costs (metadata) inside ``payload_bytes`` —
        charged once per leaf regardless of how much of it was sampled."""
        return 0

    def measure_ratio(self, params: Any,
                      max_elems: Optional[int] = RATIO_SAMPLE_ELEMS) -> float:
        """Wire bytes / raw f32 bytes, measured on a capped sample.

        The variable (per-value) payload rate is extrapolated from the
        sample; per-leaf fixed costs are added once, so many-leaf models
        are not biased by sampling.  Exact when the model fits the cap.
        """
        sample = _sample_tree(params, max_elems)
        msg = self.marshal(sample)
        overhead = self.fixed_overhead_bytes(msg)
        raw_sample = polyline.raw_bytes(sample)
        raw_full = polyline.raw_bytes(params)
        var_rate = (self.payload_bytes(msg) - overhead) / raw_sample
        return (var_rate * raw_full + overhead) / raw_full


class NoneCodec(Codec):
    """Uncompressed f32 links (the baselines' Table 2 setting)."""

    name = "none"

    def marshal(self, params):
        leaves, treedef = jax.tree.flatten(params)
        return {"leaves": [np.asarray(l) for l in leaves],
                "treedef": treedef}

    def unmarshal(self, msg):
        return jax.tree.unflatten(msg["treedef"], msg["leaves"])

    def payload_bytes(self, msg):
        return sum(l.nbytes for l in msg["leaves"])

    def measure_ratio(self, params, max_elems=RATIO_SAMPLE_ELEMS):
        return 1.0


class PolylineCodec(Codec):
    """The paper's reference compressor (compress/polyline.py)."""

    def __init__(self, precision: int = 4):
        self.precision = precision
        self.name = f"polyline:{precision}"

    def lossy(self, params):
        # the codec's exact lossy step: round to `precision` decimals.
        # Written as multiply-by-reciprocal, not division: XLA rewrites
        # x / const to x * (1/const) inside fused programs but not in
        # op-by-op dispatch, so the division form is not bitwise
        # reproducible between eager and jitted execution (the fused
        # round step requires eager == in-graph, core/executor.py).
        f = np.float32(10.0 ** self.precision)
        inv = np.float32(1.0 / (10.0 ** self.precision))
        return jax.tree.map(lambda x: jnp.round(x * f) * inv, params)

    def marshal(self, params):
        return polyline.marshal(params, self.precision)

    def unmarshal(self, msg):
        return polyline.unmarshal(msg)

    def payload_bytes(self, msg):
        return polyline.payload_bytes(msg)

    def fixed_overhead_bytes(self, msg):
        return 8 * len(msg["shapes"])  # dims metadata per leaf


class QuantizeCodec(Codec):
    """Blockwise fixed-point quantization, Pallas-kernel lossy step.

    Wire format and byte accounting come from compress/quantize.py; the
    in-graph roundtrip runs the TPU kernel in kernels/polyline_codec.py
    (``interpret=True`` executes it on CPU).
    """

    def __init__(self, bits: int = 8, interpret: bool = True):
        if not 2 <= bits <= 16:
            # the wire dtype is int8/int16; wider widths would silently
            # wrap when q is cast (quantize.compress)
            raise ValueError(f"quantize codec supports 2..16 bits, got {bits}")
        self.bits = bits
        self.interpret = interpret
        self.name = f"quantize{bits}"

    def lossy(self, params):
        from repro.kernels import ops  # lazy: keeps transport import light

        def roundtrip(x):
            q, scale = ops.compress(x, self.bits, interpret=self.interpret)
            return ops.decompress(q, scale, x.shape,
                                  interpret=self.interpret).astype(x.dtype)
        return jax.tree.map(roundtrip, params)

    def marshal(self, params):
        return quantize.compress_tree(params, self.bits)

    def unmarshal(self, msg):
        return quantize.decompress_tree(msg)

    def payload_bytes(self, msg):
        return quantize.tree_wire_bytes(msg)

    def measure_ratio(self, params, max_elems=RATIO_SAMPLE_ELEMS):
        # exact and cheap: the wire size depends only on leaf sizes
        # (ceil(n/256) blocks of 256*itemsize + 4 scale bytes, + 8
        # metadata bytes per leaf), never on the values
        itemsize = 1 if self.bits <= 8 else 2
        leaves = [np.asarray(l) for l in jax.tree.leaves(params)]
        wire = sum(-(-l.size // quantize.BLOCK)
                   * (quantize.BLOCK * itemsize + 4) for l in leaves)
        wire += 8 * len(leaves)
        return wire / polyline.raw_bytes(leaves)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., Codec]] = {}


def register_codec(name: str, factory: Callable[..., Codec]) -> None:
    _REGISTRY[name] = factory


def registered_codecs() -> List[str]:
    """Registered codec family names (the api layer's validation surface)."""
    return sorted(_REGISTRY)


register_codec("none", lambda: NoneCodec())
register_codec("polyline", lambda p=4: PolylineCodec(int(p)))
register_codec("quantize", lambda b=8: QuantizeCodec(int(b)))
register_codec("quantize8", lambda: QuantizeCodec(8))
register_codec("quantize16", lambda: QuantizeCodec(16))


def get_codec(spec: Union[str, Codec, None]) -> Codec:
    """Resolve ``'polyline'``, ``'polyline:6'``, ``'quantize8'``, a Codec
    instance, or None (identity) to a Codec."""
    if spec is None:
        return NoneCodec()
    if isinstance(spec, Codec):
        return spec
    name, _, arg = str(spec).partition(":")
    if name not in _REGISTRY:
        raise ValueError(f"unknown codec {spec!r}; "
                         f"registered: {sorted(_REGISTRY)}")
    if not arg:
        return _REGISTRY[name]()
    try:
        return _REGISTRY[name](arg)
    except (TypeError, ValueError) as e:
        raise ValueError(f"bad argument in codec spec {spec!r} "
                         f"(expected e.g. 'polyline:4', 'quantize:16'): {e}")


def cross_tier_bits(spec: Union[str, Codec]) -> int:
    """Int width for the in-SPMD cross-tier collective (core/steps.py).

    Only the quantize family can ride inside a jitted collective; polyline
    is a host-side wire codec.
    """
    codec = get_codec(spec)
    if not isinstance(codec, QuantizeCodec):
        raise ValueError(
            f"codec {codec.name!r} cannot run inside the cross-tier "
            "collective; use quantize8/quantize16")
    return codec.bits
