"""TPU-native compression codec (the polyline adaptation, DESIGN.md §HW).

The paper's polyline encoder is an ASCII varint stream — pointer-chasing,
variable-length, and hostile to vector units.  Its *information content* is
"keep ~`precision` decimal digits of each weight".  The TPU-native analogue
implemented here is blockwise fixed-point quantization:

  * split the flat weight vector into blocks of 256,
  * per-block scale s = max|x| / qmax  (qmax = 127 for int8, 32767 for int16),
  * q = round(x / s) stored as int8/int16, s as f32 (1/256 overhead).

Max error per weight is s/2 <= max|block| / (2*qmax) — the analogue of the
polyline bound 0.5*10^-p, but *relative* to the block range, which tracks
the paper's observation that non-i.i.d. weight divergence breaks fixed
absolute precision.  Everything is jnp, so it jits, vmaps over clients, and
runs *inside* the cross-tier collective (the pod-axis all-reduce moves int8,
cutting the collective roofline term ~4x vs f32 — see EXPERIMENTS.md §Perf).

A Pallas TPU kernel of the same codec lives in kernels/polyline_codec.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: jax.Array        # (n_blocks, BLOCK) int8/int16 (zero-padded tail)
    scale: jax.Array    # (n_blocks,) f32
    size: int           # original flat length
    # original shape travels out-of-band (tree metadata), like the paper's
    # "dimensions of the weights of each layer are transmitted as well".


def _qmax(bits: int) -> int:
    return (1 << (bits - 1)) - 1


def compress(x: jax.Array, bits: int = 8) -> Compressed:
    dtype = jnp.int8 if bits <= 8 else jnp.int16
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    nb = -(-n // BLOCK)
    flat = jnp.pad(flat, (0, nb * BLOCK - n))
    blocks = flat.reshape(nb, BLOCK)
    qmax = _qmax(bits)
    scale = jnp.max(jnp.abs(blocks), axis=1) / qmax
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -qmax, qmax).astype(dtype)
    return Compressed(q=q, scale=scale.astype(jnp.float32), size=n)


def decompress(c: Compressed, shape: Tuple[int, ...], dtype=jnp.float32
               ) -> jax.Array:
    flat = (c.q.astype(jnp.float32) * c.scale[:, None]).reshape(-1)[:c.size]
    return flat.reshape(shape).astype(dtype)


def wire_bytes(c: Compressed) -> int:
    return int(c.q.size * c.q.dtype.itemsize + c.scale.size * 4)


# ---------------------------------------------------------------------------
# pytree codec (uplink/downlink payloads)
# ---------------------------------------------------------------------------

def compress_tree(tree: Any, bits: int = 8):
    leaves, treedef = jax.tree.flatten(tree)
    comps = [compress(l, bits) for l in leaves]
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    return {"comps": comps, "shapes": shapes, "dtypes": dtypes,
            "treedef": treedef}


def decompress_tree(msg) -> Any:
    leaves = [decompress(c, s, d) for c, s, d in
              zip(msg["comps"], msg["shapes"], msg["dtypes"])]
    return jax.tree.unflatten(msg["treedef"], leaves)


def tree_wire_bytes(msg) -> int:
    return sum(wire_bytes(c) for c in msg["comps"]) + 8 * len(msg["shapes"])


# ---------------------------------------------------------------------------
# in-graph codec for compressed collectives (jit-friendly, fixed shapes)
# ---------------------------------------------------------------------------

def fake_quantize(x: jax.Array, bits: int = 8) -> jax.Array:
    """Quantize-dequantize in-graph (straight-through values).

    Used to model the paper's lossy link inside a jitted train step: the
    cross-tier aggregation operates on codec-roundtripped weights, and the
    collective itself can be performed on the int payload.
    """
    return decompress(compress(x, bits), x.shape, x.dtype)


def error_bound(x: jax.Array, bits: int = 8) -> jax.Array:
    """Per-block worst-case absolute error of the codec."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    nb = -(-n // BLOCK)
    flat = jnp.pad(flat, (0, nb * BLOCK - n))
    blocks = flat.reshape(nb, BLOCK)
    return jnp.max(jnp.abs(blocks), axis=1) / _qmax(bits) * 0.5
