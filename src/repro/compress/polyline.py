"""Faithful Encoded Polyline Algorithm codec (FedAT §4.3).

Implements Google's polyline encoding applied to flattened model weights:
each value is rounded to ``precision`` decimal places, delta-encoded against
the previous value, zig-zag mapped, split into 5-bit chunks (LSB first, with
a continuation bit), and emitted as ASCII ``chr(chunk + 63)``.

This is the paper's reference compressor: lossy with max error
0.5 * 10**-precision per weight, compression ratio up to ~3.5x against f32
text/wire encodings.  The TPU-native equivalent used inside collectives is
in :mod:`repro.compress.quantize` (see DESIGN.md §Hardware-adaptation).

``encode_values``/``decode_values`` are numpy-vectorized over the whole
value stream (the scalar reference implementations are kept as
``encode_values_ref``/``decode_values_ref`` and cross-checked in tests);
both require the zig-zagged deltas to fit in int64, which holds for any
weight stream with ``|delta| * 10**precision < 2**62``.

Marshalling: a pytree is flattened leaf-by-leaf; each leaf's shape travels
with its encoded payload so the receiver can unmarshal (paper steps 1-3).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import numpy as np


def encode_values(values: np.ndarray, precision: int = 4) -> str:
    """Polyline-encode a 1-D float array (vectorized)."""
    factor = 10 ** precision
    ints = np.round(np.asarray(values, np.float64) * factor).astype(np.int64)
    if ints.size == 0:
        return ""
    deltas = np.diff(ints, prepend=np.int64(0))
    v = (deltas << 1) ^ (deltas >> 63)              # zig-zag, branchless

    # chunks emitted per value = #significant 5-bit groups (at least 1:
    # a zero delta still emits one chunk); cap the matrix width at the
    # stream's actual maximum instead of the int64 worst case of 13
    width = max(1, -(-int(v.max()).bit_length() // 5))
    chunks = np.empty((len(v), width), np.uint8)
    valid = np.empty((len(v), width), bool)          # chunk j emitted?
    valid[:, 0] = True
    for j in range(width):
        chunks[:, j] = (v >> (5 * j)) & 0x1F
        if j:  # value needs chunk j iff it has significant bits >= 5j
            np.greater_equal(v, np.int64(1) << (5 * j), out=valid[:, j])
    cont = np.zeros_like(valid)                      # continuation bit
    cont[:, :-1] = valid[:, 1:]
    sym = (chunks | (cont.view(np.uint8) << 5)) + 63
    # boolean indexing flattens row-major: per-value chunk order, then
    # value order — exactly the scalar emission order
    return sym[valid].tobytes().decode("ascii")


def decode_values(encoded: str, precision: int = 4) -> np.ndarray:
    """Inverse of :func:`encode_values` (vectorized)."""
    factor = 10 ** precision
    if not encoded:
        return np.zeros(0, np.float32)
    b = np.frombuffer(encoded.encode("ascii"), np.uint8).astype(np.int64) - 63
    ends = (b & 0x20) == 0                     # last chunk of each value
    # value index of each chunk, and its 5-bit position within the value
    gid = np.concatenate([[0], np.cumsum(ends[:-1])])
    starts = np.concatenate([[0], np.nonzero(ends)[0][:-1] + 1])
    pos = np.arange(len(b)) - starts[gid]

    res = np.zeros(int(ends.sum()), np.uint64)
    np.add.at(res, gid,
              (b & 0x1F).astype(np.uint64) << (pos.astype(np.uint64)
                                               * np.uint64(5)))
    res = res.astype(np.int64)
    delta = np.where(res & 1, ~(res >> 1), res >> 1)
    return (np.cumsum(delta) / factor).astype(np.float32)


# ---------------------------------------------------------------------------
# scalar reference implementations (spec + equivalence oracle in tests)
# ---------------------------------------------------------------------------

def encode_values_ref(values: np.ndarray, precision: int = 4) -> str:
    factor = 10 ** precision
    ints = np.round(np.asarray(values, np.float64) * factor).astype(np.int64)
    deltas = np.diff(ints, prepend=np.int64(0))
    out: List[str] = []
    for d in deltas:
        v = int(d) << 1
        if d < 0:
            v = ~v
        while v >= 0x20:
            out.append(chr((0x20 | (v & 0x1F)) + 63))
            v >>= 5
        out.append(chr(v + 63))
    return "".join(out)


def decode_values_ref(encoded: str, precision: int = 4) -> np.ndarray:
    factor = 10 ** precision
    vals: List[float] = []
    acc = 0
    idx = 0
    n = len(encoded)
    while idx < n:
        shift = 0
        result = 0
        while True:
            b = ord(encoded[idx]) - 63
            idx += 1
            result |= (b & 0x1F) << shift
            shift += 5
            if b < 0x20:
                break
        delta = ~(result >> 1) if (result & 1) else (result >> 1)
        acc += delta
        vals.append(acc / factor)
    return np.asarray(vals, np.float32)


# ---------------------------------------------------------------------------
# marshalling / unmarshalling (paper §4.3 steps 1-3)
# ---------------------------------------------------------------------------

def marshal(params: Any, precision: int = 4) -> Dict[str, Any]:
    """Pytree -> {payloads: [str], shapes, treedef-token}. Lossy."""
    leaves, treedef = jax.tree.flatten(params)
    payloads, shapes, dtypes = [], [], []
    for leaf in leaves:
        arr = np.asarray(leaf)
        payloads.append(encode_values(arr.reshape(-1), precision))
        shapes.append(arr.shape)
        dtypes.append(str(arr.dtype))
    return {"payloads": payloads, "shapes": shapes, "dtypes": dtypes,
            "treedef": treedef, "precision": precision}


def unmarshal(msg: Dict[str, Any]) -> Any:
    leaves = []
    for payload, shape, dtype in zip(msg["payloads"], msg["shapes"],
                                     msg["dtypes"]):
        arr = decode_values(payload, msg["precision"])
        leaves.append(arr.reshape(shape).astype(dtype))
    return jax.tree.unflatten(msg["treedef"], leaves)


def payload_bytes(msg: Dict[str, Any]) -> int:
    """Wire size: ASCII payloads + 8 bytes of dims metadata per leaf."""
    return sum(len(p) for p in msg["payloads"]) + 8 * len(msg["shapes"])


def raw_bytes(params: Any) -> int:
    return sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))


def roundtrip_error(params: Any, precision: int = 4) -> float:
    rt = unmarshal(marshal(params, precision))
    return max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
               for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rt)))
