from repro.compress import polyline, quantize, transport  # noqa: F401
from repro.compress.transport import Codec, get_codec  # noqa: F401
