from repro.compress import polyline, quantize  # noqa: F401
