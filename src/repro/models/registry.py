"""FLModel registry: the federated path to the model zoo.

The engine, executor, and spec API never name a concrete architecture —
they consume a bound :class:`FLModel`: a small protocol of pure functions

  * ``init_params(key)``                 -> params pytree
  * ``apply(params, x)``                 -> logits
  * ``loss(params, x, y, mask)``         -> masked scalar objective
  * ``eval_metrics(params, x, y, mask)`` -> per-client accuracy scalar
  * ``batch_shape`` / ``batch_dtype``    -> per-sample input contract

over *arbitrary pytree params* (dicts of arrays, scan-stacked layer
trees, anything ``jax.tree`` traverses).  Entries are registered as
factories ``make(dims: DataDims) -> FLModel`` under a string name — the
name the spec's ``data.model`` field resolves through — so adding a
model to the federated path is one :func:`register_model` call; the
partitioner, the fused round step, client sharding, codecs, and the
provenance hashing all compose unchanged (DESIGN.md §Model-registry).

Registered here:

  * ``cnn``     — the paper's CIFAR/Fashion-MNIST CNN (``models/cnn.py``),
                  image data (was ``task="image"``).
  * ``logreg``  — the paper's Sentiment140 logistic regression, feature
                  vectors (was ``task="text"``).
  * ``tiny_lm`` — a tiny dense causal LM through the repo's LM facade
                  (``models/lm.py`` / ``models/transformer.py``,
                  config ``configs/tiny_lm.py``) over class-conditional
                  token streams (``data/pipeline.py``).

The ``cnn``/``logreg`` losses are op-for-op the pre-registry client
objective, so pre-existing image/text specs reproduce their trajectories
bitwise through this indirection (tests/test_model_registry.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataDims:
    """The data-plane knobs a model needs to size itself (a subset of
    ``DataSpec`` — models never see the spec layer)."""
    n_classes: int = 10
    image_hw: int = 12
    n_features: int = 128
    vocab_size: int = 64
    seq_len: int = 16
    #: attention path for transformer-family models ("auto" | "flash" |
    #: "reference", configs/base.py ATTENTION_BACKENDS); non-attention
    #: models ignore it
    attention_backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class FLModel:
    """One model bound to a scenario's :class:`DataDims`.

    ``loss`` is the client-local objective the proximal term is added to
    (core/clients.py); its reduction must weight samples by ``mask`` so
    the executor's zero-weight padding slots stay exactly neutral.
    ``eval_metrics`` is the per-client accuracy the engine's periodic
    eval vmaps over the test stacks.
    """
    name: str
    #: what the federated partitioner synthesizes: "image" (H,W,3 float)
    #: | "features" (F float) | "tokens" (S int32) — data/federated.py
    data_kind: str
    init_params: Callable[[jax.Array], Any]
    apply: Callable[[Any, jax.Array], jax.Array]
    loss: Callable[[Any, jax.Array, jax.Array, jax.Array], jax.Array]
    eval_metrics: Callable[[Any, jax.Array, jax.Array, jax.Array],
                           jax.Array]
    #: per-sample input shape/dtype (the padded train stacks are
    #: (n_clients, cap) + batch_shape arrays of batch_dtype)
    batch_shape: Tuple[int, ...]
    batch_dtype: Any = np.float32
    #: the bound :class:`~repro.configs.base.ModelConfig` for models that
    #: ride the LM facade (``models/lm.py``) — what the serving plane
    #: (``repro.serve``) rebuilds prefill/decode from.  ``None`` marks a
    #: model with no decode path (cnn/logreg are not servable).
    config: Any = None


#: name -> factory(dims) -> FLModel; the extension point data.model
#: resolves through.
MODELS: Dict[str, Callable[[DataDims], FLModel]] = {}


def register_model(name: str,
                   factory: Callable[[DataDims], FLModel]) -> None:
    """Register a model factory under ``name`` (error on duplicates)."""
    if name in MODELS:
        raise ValueError(f"model {name!r} is already registered")
    MODELS[name] = factory


def registered_models() -> List[str]:
    return sorted(MODELS)


def build_model(name: str, dims: DataDims) -> FLModel:
    """Resolve ``name`` and bind it to ``dims`` (the SimEnv entry point)."""
    if name not in MODELS:
        raise ValueError(f"unknown model {name!r}; "
                         f"registered: {registered_models()}")
    return MODELS[name](dims)


# ---------------------------------------------------------------------------
# classification objective (shared by cnn / logreg)
# ---------------------------------------------------------------------------
# These bodies are op-for-op the pre-registry client loss/eval, which is
# what keeps the engine-parity oracle bitwise through the registry path.

def _classification_loss(apply_fn):
    def loss(params, x, y, mask):
        logits = apply_fn(params, x)
        labels = jax.nn.one_hot(y, logits.shape[-1])
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.sum(labels * logp, axis=-1)
        return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss


def _classification_eval(apply_fn):
    def eval_metrics(params, x, y, mask):
        pred = jnp.argmax(apply_fn(params, x), axis=-1)
        return jnp.sum((pred == y) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return eval_metrics


def _make_cnn(dims: DataDims) -> FLModel:
    from repro.models import cnn
    in_shape = (dims.image_hw, dims.image_hw, 3)
    return FLModel(
        name="cnn", data_kind="image",
        init_params=lambda key: cnn.cnn_init(
            key, in_shape=in_shape, n_classes=dims.n_classes),
        apply=cnn.cnn_apply,
        loss=_classification_loss(cnn.cnn_apply),
        eval_metrics=_classification_eval(cnn.cnn_apply),
        batch_shape=in_shape)


def _make_logreg(dims: DataDims) -> FLModel:
    from repro.models import cnn
    return FLModel(
        name="logreg", data_kind="features",
        init_params=lambda key: cnn.logreg_init(
            key, n_features=dims.n_features, n_classes=dims.n_classes),
        apply=cnn.logreg_apply,
        loss=_classification_loss(cnn.logreg_apply),
        eval_metrics=_classification_eval(cnn.logreg_apply),
        batch_shape=(dims.n_features,))


# ---------------------------------------------------------------------------
# tiny_lm: the LM facade on the federated path
# ---------------------------------------------------------------------------

def _make_tiny_lm(dims: DataDims, arch: str = "tiny-lm",
                  name: str = "tiny_lm") -> FLModel:
    """A tiny dense causal LM (``configs/tiny_lm.py``) trained federated
    on class-conditional token streams.

    Reuses the repo's LM stack end to end: params come from
    :func:`repro.models.lm.init_params` (scan-stacked layer pytree — the
    client update, codecs, and Eq. 3/4 averages are pytree-generic), the
    forward pass is :func:`repro.models.transformer.forward_train`, and
    the objective is next-token cross-entropy averaged per sample then
    mask-weighted across the client's (padded) sample slots.

    ``dims.attention_backend`` lands on the bound :class:`ModelConfig`,
    so a spec's ``data.attention_backend`` picks the attention path
    (flash kernel layer vs. the reference parity oracle) for every
    client step in the federated run.
    """
    from repro.configs.registry import get_config
    from repro.models import lm, transformer

    cfg = get_config(arch).replace(
        vocab_size=dims.vocab_size,
        attention_backend=dims.attention_backend)

    def apply(params, x):
        """x: (B, S) int32 tokens -> logits (B, S, V)."""
        feats, _, _ = transformer.forward_train(
            cfg, params, {"tokens": x}, tp=1)
        return transformer.lm_head(cfg, params, feats).astype(jnp.float32)

    def _per_sample_ce(params, x):
        logits = apply(params, x)                     # (B, S, V)
        logp = jax.nn.log_softmax(logits[:, :-1])
        labels = x[:, 1:]
        nll = -jnp.take_along_axis(logp, labels[..., None],
                                   axis=-1)[..., 0]   # (B, S-1)
        return jnp.mean(nll, axis=-1)                 # (B,)

    def loss(params, x, y, mask):
        del y  # next-token objective; the class label only shapes the data
        ce = _per_sample_ce(params, x)
        return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    def eval_metrics(params, x, y, mask):
        del y
        logits = apply(params, x)
        pred = jnp.argmax(logits[:, :-1], axis=-1)    # (B, S-1)
        ok = jnp.mean((pred == x[:, 1:]).astype(jnp.float32), axis=-1)
        return jnp.sum(ok * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    return FLModel(
        name=name, data_kind="tokens",
        init_params=lambda key: lm.init_params(
            cfg, key, tp=1, dtype=jnp.float32),
        apply=apply, loss=loss, eval_metrics=eval_metrics,
        batch_shape=(dims.seq_len,), batch_dtype=np.int32,
        config=cfg)


def _make_tiny_lm_long(dims: DataDims) -> FLModel:
    """The long-sequence tiny LM (arch ``tiny-lm-long``): same stack,
    attn_chunk tuned for seq_len ~128 — the config where flash-vs-
    reference attention shows up in end-to-end events/s."""
    return _make_tiny_lm(dims, arch="tiny-lm-long", name="tiny_lm_long")


register_model("cnn", _make_cnn)
register_model("logreg", _make_logreg)
register_model("tiny_lm", _make_tiny_lm)
register_model("tiny_lm_long", _make_tiny_lm_long)

#: the ``task`` values spec versions 1/2 used, mapped to registry names
#: (the ``data.task`` deprecation shim in api/spec.py resolves through
#: this, so there is exactly one place the mapping is written down).
LEGACY_TASKS: Dict[str, str] = {"image": "cnn", "text": "logreg"}
