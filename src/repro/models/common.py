"""Shared model building blocks: param specs, init, norms, RoPE, losses.

Parameters are plain nested dicts of ``jnp`` arrays.  Every model module
declares a same-structure tree of :class:`PSpec` (shape + logical axes +
init style); generic helpers materialize arrays / shardings from it, so
model code never hand-writes PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.runtime import sharding as shd


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | small
    scale: Optional[float] = None  # override fan-in scaling


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def init_from_specs(specs: Dict[str, Any], key: jax.Array, dtype=jnp.float32):
    """Materialize a param tree from a spec tree (deterministic per-path keys)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_pspec)
    keys = jax.random.split(key, len(leaves))

    def mk(spec: PSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        if len(spec.shape) >= 2:
            fan_in = spec.shape[-2]
        std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
        if spec.init == "small":
            std = 0.02
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [mk(s, k) for s, k in zip(leaves, keys)])


def axes_from_specs(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_pspec)


def shapes_from_specs(specs, dtype=jnp.float32):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
                        is_leaf=is_pspec)


def shardings_from_specs(specs, mesh=None):
    return jax.tree.map(lambda s: shd.logical_sharding(s.axes, mesh), specs,
                        is_leaf=is_pspec)


def param_bytes(specs, bytes_per_el=2) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_pspec)
    return sum(math.prod(s.shape) for s in leaves) * bytes_per_el


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * gamma.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    dt = x.dtype
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def swiglu(x: jax.Array, w_gate: jax.Array, w_in: jax.Array, w_out: jax.Array,
           act: str = "silu") -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    h = jnp.einsum("...d,df->...f", x, w_in)
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    h = shd.shard(g * h, "batch", None, "tp")
    return jnp.einsum("...f,fd->...d", h, w_out)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None,
                          vocab_size: Optional[int] = None) -> jax.Array:
    """Mean CE over masked positions; safe with TP-padded vocab.

    logits: (..., V_padded) possibly vocab-sharded; labels int (...,).
    Padded vocab entries are excluded via a large-negative bias.
    """
    logits = logits.astype(jnp.float32)
    if vocab_size is not None and vocab_size < logits.shape[-1]:
        pad = logits.shape[-1] - vocab_size
        neg = jnp.full((pad,), -1e9, jnp.float32)
        logits = logits + jnp.concatenate([jnp.zeros((vocab_size,)), neg])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
