"""Mixture-of-experts FFN (GShard/Switch-style dense dispatch).

Two sharding modes, chosen per-arch by divisibility against the TP degree:
  * EP  — experts sharded over the ``model`` axis (deepseek-moe: 64 % 16 == 0).
          The combine einsum contracts the sharded expert dim -> one
          all-reduce over ``model`` (the SPMD analogue of the MoE all-to-all).
  * TPF — experts replicated, per-expert d_ff sharded over ``model``
          (granite-moe: 40 experts don't divide 16, but d_ff=512 does).

Token-choice top-k routing with per-group capacity; dropped tokens fall
through on the residual path.  Groups are seq-chunks so the capacity cumsum
never crosses a sharded dim during training/prefill.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import PSpec
from repro.runtime import sharding as shd

GROUP = 256  # tokens per routing group (capacity granularity)


def use_ep(cfg: ModelConfig, tp: int) -> bool:
    return tp > 1 and cfg.moe.n_experts % tp == 0


def moe_specs(cfg: ModelConfig, tp: int, prefix_layers: Tuple[int, ...] = ()
              ) -> Dict[str, PSpec]:
    m, d = cfg.moe, cfg.d_model
    L = prefix_layers
    lax_ = tuple("layers" for _ in L)
    e_ax = ("experts", "fsdp", None) if use_ep(cfg, tp) else (None, "fsdp", "tp")
    eo_ax = ("experts", None, "fsdp") if use_ep(cfg, tp) else (None, "tp", "fsdp")
    sp = {
        "router": PSpec(L + (d, m.n_experts), lax_ + ("fsdp", None), init="small"),
        "w_gate": PSpec(L + (m.n_experts, d, m.expert_d_ff), lax_ + e_ax),
        "w_in": PSpec(L + (m.n_experts, d, m.expert_d_ff), lax_ + e_ax),
        "w_out": PSpec(L + (m.n_experts, m.expert_d_ff, d), lax_ + eo_ax),
    }
    if m.n_shared_experts:
        ff = m.n_shared_experts * (m.shared_d_ff or m.expert_d_ff)
        sp["ws_gate"] = PSpec(L + (d, ff), lax_ + ("fsdp", "tp"))
        sp["ws_in"] = PSpec(L + (d, ff), lax_ + ("fsdp", "tp"))
        sp["ws_out"] = PSpec(L + (ff, d), lax_ + ("tp", "fsdp"))
    return sp


def _route(cfg: ModelConfig, router_w, xg: jax.Array,
           dropless: bool = False):
    """xg: (..., G, d) -> combine (..., G, E, C), dispatch bools, aux losses."""
    m = cfg.moe
    G = xg.shape[-2]
    if dropless:
        cap = G  # decode: a dropped token is a corrupted output
    else:
        cap = max(int(m.capacity_factor * m.top_k * G / m.n_experts), 1)
    rdt = jnp.dtype(m.route_dtype)  # f32 baseline / bf16 (int-exact <= 256)

    logits = jnp.einsum("...gd,de->...ge", xg.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, m.top_k)           # (..., G, k)

    # Accumulate the (..., G, E, C) combine tensor one k-slice at a time so
    # the (..., G, k, E, C) outer product never materializes.  top-1
    # assignments win expert capacity over top-2, etc.
    combine = jnp.zeros(xg.shape[:-1] + (m.n_experts, cap), rdt)
    filled = jnp.zeros(xg.shape[:-2] + (m.n_experts,), rdt)
    oh_sum = jnp.zeros(xg.shape[:-2] + (m.n_experts,), jnp.float32)
    for kk in range(m.top_k):
        oh = jax.nn.one_hot(idx[..., kk], m.n_experts, dtype=rdt)
        pos = jnp.cumsum(oh, axis=-2) - 1.0 + filled[..., None, :]  # (...,G,E)
        keep = (pos < cap) & (oh > 0)
        slot = jnp.clip((pos * oh).sum(-1), 0, cap - 1).astype(jnp.int32)
        slot_oh = jax.nn.one_hot(slot, cap, dtype=rdt)              # (...,G,C)
        kept_gate = gate_vals[..., kk].astype(rdt) * keep.sum(-1)   # (...,G)
        combine = combine + (oh * keep)[..., None] * \
            (kept_gate[..., None] * slot_oh)[..., None, :]
        filled = filled + oh.sum(axis=-2)
        oh_sum = oh_sum + oh.sum(axis=-2).astype(jnp.float32)
    dispatch = combine > 0

    # aux losses (Switch-style load balance + router z-loss)
    me = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    ce = oh_sum.mean(axis=tuple(range(oh_sum.ndim - 1))) / G * m.top_k
    aux = m.n_experts * jnp.sum(me * ce) * m.aux_loss_coef
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_coef
    return combine, dispatch, aux + zloss


def moe_ffn(cfg: ModelConfig, p, x: jax.Array, tp: int
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).  Groups along seq (or batch if S==1)."""
    B, S, d = x.shape
    if S >= GROUP and S % GROUP == 0:
        xg = x.reshape(B, S // GROUP, GROUP, d)
        b_ax = "batch"      # group-batch dim n == batch rows
        dropless = False
    else:
        xg = x.reshape(1, 1, B * S, d)  # decode / tiny shapes: one group
        b_ax = None
        dropless = True     # decode must not drop tokens
    xg = shd.shard(xg, b_ax, None, None if b_ax else "batch", None)
    combine, dispatch, aux = _route(cfg, p["router"], xg, dropless)
    combine = shd.shard(combine, b_ax, None, None, None, None)
    combine = combine.astype(x.dtype)

    # dispatch: (n, g, G, E, C) x tokens (n, g, G, d) -> (n, g, E, C, d)
    xe = jnp.einsum("ngtec,ngtd->ngecd", dispatch.astype(x.dtype), xg)
    xe = shd.shard(xe, b_ax, None, "experts" if use_ep(cfg, tp) else None,
                   None, None)
    h = jnp.einsum("ngecd,edf->ngecf", xe, p["w_gate"])
    u = jnp.einsum("ngecd,edf->ngecf", xe, p["w_in"])
    h = jax.nn.silu(h) * u
    ye = jnp.einsum("ngecf,efd->ngecd", h, p["w_out"])
    y = jnp.einsum("ngtec,ngecd->ngtd", combine, ye)
    y = y.reshape(B, S, d)

    if cfg.moe.n_shared_experts:
        g = jnp.einsum("bsd,df->bsf", x, p["ws_gate"])
        u2 = jnp.einsum("bsd,df->bsf", x, p["ws_in"])
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u2, p["ws_out"])
    return shd.shard(y, "batch", None, None), aux
