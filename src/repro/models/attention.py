"""GQA/MQA attention: chunked full/windowed prefill + cached decode.

Design notes (see DESIGN.md §TP-scheme):
  * Query heads are padded up to a multiple of the TP degree; padded heads
    have zero projections in and out, so they contribute nothing to the
    output (the wasted FLOPs are *visible* in the roofline ratio on purpose).
  * KV heads are sharded over the model axis iff divisible by it; otherwise
    KV projections are replicated and the decode KV *cache* is sharded along
    the sequence dim instead ("kv_seq"), which GSPMD supports by inserting
    max/sum all-reduces inside the softmax.
  * Prefill uses a query-chunked lax.scan so the (S x T) logits never
    materialize; sliding-window configs slice a (W + C)-slab of K/V per
    chunk, making SWA prefill cost O(S*W) instead of O(S^2).
  * Decode updates the cache with a `where(iota == pos)` one-hot write: no
    dynamic-slice on a sharded dim, hence no surprise all-gathers.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTENTION_BACKENDS, ModelConfig
from repro.kernels import ops as kops
from repro.models.common import PSpec, apply_rope
from repro.runtime import sharding as shd

NEG_INF = -1e9


def resolve_attention_backend(cfg: ModelConfig, tp: int) -> str:
    """Resolve ``cfg.attention_backend`` to the backend actually used.

    ``reference`` is the naive chunked softmax path below (the bitwise
    engine-parity oracle).  ``flash`` routes through the kernel layer
    (:func:`repro.kernels.ops.attention`) — but only under the tp == 1
    contract: the reference path owns the padded-head / kv_seq sharding
    story (DESIGN.md §TP-scheme), so with a model axis both ``auto`` and
    an explicit ``flash`` fall back to reference rather than hand GSPMD a
    repeat/transpose it would ring-allgather.
    """
    be = getattr(cfg, "attention_backend", "auto")
    if be not in ATTENTION_BACKENDS:
        raise ValueError(
            f"unknown attention_backend {be!r}; expected one of "
            f"{ATTENTION_BACKENDS}")
    if be == "reference" or tp > 1:
        return "reference"
    return "flash"


def _exact_attend(cfg: ModelConfig) -> bool:
    """Use the shape-stable ``_attend`` formulation iff the spec asked
    for the bitwise oracle by name.  ``auto``/``flash`` (and the tp>1
    reference fallback) keep the faster dots — only an explicit
    ``attention_backend="reference"`` buys cross-shape bitwise parity."""
    return getattr(cfg, "attention_backend", "auto") == "reference"


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig, tp: int, prefix_layers: Tuple[int, ...] = ()
               ) -> Dict[str, PSpec]:
    """Param specs for one attention block (optionally stacked over layers)."""
    d, hd, kv = cfg.d_model, cfg.head_dim, cfg.n_kv_heads
    hp = cfg.padded_heads(tp)
    kv_ax = "tp" if cfg.kv_sharded(tp) else None
    L = prefix_layers
    lax_ = tuple("layers" for _ in L)
    sp = {
        "wq": PSpec(L + (d, hp * hd), lax_ + ("fsdp", "tp")),
        "wk": PSpec(L + (d, kv * hd), lax_ + ("fsdp", kv_ax)),
        "wv": PSpec(L + (d, kv * hd), lax_ + ("fsdp", kv_ax)),
        "wo": PSpec(L + (hp * hd, d), lax_ + ("tp", "fsdp")),
    }
    if cfg.qkv_bias:
        sp["bq"] = PSpec(L + (hp * hd,), lax_ + ("tp",), init="zeros")
        sp["bk"] = PSpec(L + (kv * hd,), lax_ + (kv_ax,), init="zeros")
        sp["bv"] = PSpec(L + (kv * hd,), lax_ + (kv_ax,), init="zeros")
    return sp


def cache_axes(cfg: ModelConfig, tp: int) -> Tuple[Optional[str], ...]:
    """Logical axes of a (B, T, kv, hd) KV cache slab."""
    if cfg.kv_sharded(tp):
        return ("cache_batch", None, "tp", None)
    return ("cache_batch", "kv_seq", None, None)


class KVCache(NamedTuple):
    """Per-layer KV cache. k/v: (B, T, kv, hd); pos: scalar int32 next index.

    For sliding-window configs T == window and writes wrap (ring buffer);
    ``positions`` tracks the absolute position stored in each slot (-1 empty).
    """
    k: jax.Array
    v: jax.Array
    positions: jax.Array  # (B, T) int32


def init_cache(cfg: ModelConfig, batch: int, max_len: int, tp: int,
               dtype=jnp.bfloat16, stacked: int = 0) -> KVCache:
    T = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    lead = (stacked,) if stacked else ()
    k = jnp.zeros(lead + (batch, T, kv, hd), dtype)
    pos = jnp.full(lead + (batch, T), -1, jnp.int32)
    return KVCache(k=k, v=k, positions=pos)


# ---------------------------------------------------------------------------
# core math
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array,
                 tp: int):
    """x: (B, S, d) -> q: (B,S,kv,G,hd), k/v: (B,S,kv,hd), RoPE applied."""
    hd, kv = cfg.head_dim, cfg.n_kv_heads
    hp = cfg.padded_heads(tp)
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    kv_ax = "tp" if cfg.kv_sharded(tp) else None
    q = shd.shard(q, "batch", None, "tp")
    k = shd.shard(k, "batch", None, kv_ax)
    v = shd.shard(v, "batch", None, kv_ax)
    q = q.reshape(*q.shape[:2], hp, hd)
    k = k.reshape(*k.shape[:2], kv, hd)
    v = v.reshape(*v.shape[:2], kv, hd)
    if cfg.causal or cfg.family in ("audio",):  # RoPE everywhere (see DESIGN.md)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = q.reshape(*q.shape[:2], kv, hp // kv, hd)
    return q, k, v


def _attend(q, k, v, mask, exact: bool = False):
    """q: (B,C,kv,G,hd), k/v: (B,T,kv,hd), mask: (B?,C,T) bool -> (B,C,kv,G,hd).

    ``exact`` selects a bitwise *shape-stable* evaluation: the two
    contractions become broadcast-multiply + ``jnp.sum`` reductions
    instead of ``dot_general``.  XLA's dot emission (kernel choice,
    operand layouts, accumulation grouping) depends on the query-chunk
    length, so a C=1 decode step rounds differently from the same
    position inside a C=S prefill; an explicit last/penultimate-axis
    reduce is emitted identically for every C.  This is what lets
    serving's prefill+decode logits bitwise-match a full forward pass
    (tests/test_serve.py).  The reference backend — the parity oracle —
    pays the (fused, never materialized at (C,T,hd)) elementwise cost;
    the flash path keeps the dots.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    if exact:
        # logits[b,k,g,c,t] = sum_h q[b,c,k,g,h] * k[b,t,k,h]
        qx = q.transpose(0, 2, 3, 1, 4)[:, :, :, :, None, :]  # (B,kv,G,C,1,hd)
        kx = k.transpose(0, 2, 1, 3)[:, :, None, None, :, :]  # (B,kv,1,1,T,hd)
        logits = jnp.sum((qx * kx).astype(jnp.float32), axis=-1) * scale
        logits = jnp.where(mask[:, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        # out[b,c,k,g,h] = sum_t probs[b,k,g,c,t] * v[b,t,k,h]
        vx = v.transpose(0, 2, 1, 3)[:, :, None, None, :, :]  # (B,kv,1,1,T,hd)
        out = jnp.sum(probs[..., None] * vx, axis=-2)         # (B,kv,G,C,hd)
        return out.transpose(0, 3, 1, 2, 4)
    logits = jnp.einsum("bckgh,btkh->bkgct", q, k).astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgct,btkh->bckgh", probs, v)


def full_attention(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array,
                   tp: int, prefix_len: int = 0) -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B, S, d)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions, tp)
    C = min(cfg.attn_chunk, S)
    W = cfg.swa_window

    if resolve_attention_backend(cfg, tp) == "flash":
        # kernel-layer contract: q (B, S, H, hd), k/v (B, S, kv, hd).
        # q's (kv, G) grouping flattens kv-major, matching the KV-head
        # expansion order inside the kernel wrappers.
        qf = q.reshape(B, S, -1, cfg.head_dim)
        impl = "auto"
        if prefix_len and kops.default_attention_impl() != "blocked":
            impl = "blocked"  # the Pallas kernel has no prefix-LM mask
        out = kops.attention(qf, k, v, causal=cfg.causal, window=W,
                             impl=impl, block=C, prefix_len=prefix_len)
        out = out.reshape(B, S, -1)
        out = shd.shard(out, "batch", None, "tp")
        return jnp.einsum("bsh,hd->bsd", out, p["wo"])

    def block_mask(pos_q, pos_kv):
        m = jnp.ones((pos_q.shape[0], pos_kv.shape[0]), bool)
        if cfg.causal:
            m = pos_q[:, None] >= pos_kv[None, :]
            if prefix_len:  # prefix-LM: bidirectional over the prefix
                m = m | (pos_kv[None, :] < prefix_len)
        if W is not None:
            m = m & (pos_q[:, None] - pos_kv[None, :] < W)
        return m

    exact = _exact_attend(cfg)
    if S <= C:
        out = _attend(q, k, v, block_mask(positions, positions)[None],
                      exact=exact)
    else:
        n = -(-S // C)  # ceil: pad the query side to a chunk multiple
        Sp = n * C
        qp = jnp.pad(q, ((0, 0), (0, Sp - S)) + ((0, 0),) * (q.ndim - 2)) \
            if Sp != S else q
        qc = qp.reshape(B, n, C, *q.shape[2:]).transpose(1, 0, 2, 3, 4, 5)

        if W is not None and W + C < S:
            slab = W + C  # windowed: only a slab of K/V is live per chunk

            def step(_, iq):
                i, qi = iq
                start = jnp.maximum(i * C + C - slab, 0)
                ks = jax.lax.dynamic_slice_in_dim(k, start, slab, axis=1)
                vs = jax.lax.dynamic_slice_in_dim(v, start, slab, axis=1)
                pq = i * C + jnp.arange(C)
                pkv = start + jnp.arange(slab)
                return None, _attend(qi, ks, vs, block_mask(pq, pkv)[None],
                                     exact=exact)
        else:
            def step(_, iq):
                i, qi = iq
                pq = i * C + jnp.arange(C)
                return None, _attend(qi, k, v,
                                     block_mask(pq, positions)[None],
                                     exact=exact)

        _, oc = jax.lax.scan(step, None, (jnp.arange(n), qc),
                             unroll=True if cfg.unroll_scans else 1)
        out = oc.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp, *oc.shape[3:])
        out = out[:, :S]

    out = out.reshape(B, S, -1)
    out = shd.shard(out, "batch", None, "tp")
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def prefill_attention(cfg: ModelConfig, p, x, positions, tp: int,
                      cache: KVCache, prefix_len: int = 0
                      ) -> Tuple[jax.Array, KVCache]:
    """Full attention + populate the cache with this segment's K/V.

    The slots written are statically known (positions 0..S-1), so the ring
    placement is a static pad + roll — no one-hot scatter FLOPs.
    """
    B, S, _ = x.shape
    out = full_attention(cfg, p, x, positions, tp, prefix_len)
    # recompute k/v for the cache write (cheap vs attention itself)
    _, k, v = _project_qkv(cfg, p, x, positions, tp)
    T = cache.k.shape[1]
    keep = min(S, T)
    k, v = k[:, -keep:], v[:, -keep:]
    pos_tail = jnp.arange(S - keep, S, dtype=jnp.int32)
    if keep < T:  # right-pad empty slots
        padw = ((0, 0), (0, T - keep), (0, 0), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        pos_tail = jnp.pad(pos_tail, (0, T - keep), constant_values=-1)
    shift = (S - keep) % T  # first kept position lands at this slot
    if shift:
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
        pos_tail = jnp.roll(pos_tail, shift)
    ck = shd.shard(k.astype(cache.k.dtype), *cache_axes(cfg, tp))
    cv = shd.shard(v.astype(cache.v.dtype), *cache_axes(cfg, tp))
    cpos = jnp.broadcast_to(pos_tail[None, :], (B, T))
    return out, KVCache(k=ck, v=cv, positions=cpos)


def decode_attention(cfg: ModelConfig, p, x: jax.Array, pos: jax.Array,
                     tp: int, cache: KVCache) -> Tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, d); pos: scalar int32, or (B,) int32 for
    per-slot positions (continuous batching: a recycled slot restarts at 0
    while its neighbours keep decoding — RoPE, the ring write, and the
    validity mask all follow each slot's own position)."""
    B = x.shape[0]
    T = cache.k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    pos_b = jnp.broadcast_to(pos, (B,)) if pos.ndim == 0 else pos  # (B,)
    positions = pos_b[:, None]                                    # (B, 1)
    q, k, v = _project_qkv(cfg, p, x, positions, tp)  # q:(B,1,kv,G,hd)

    slot = (pos_b % T).astype(jnp.int32)                          # (B,)
    iota = jnp.arange(T, dtype=jnp.int32)
    hit_bt = iota[None, :] == slot[:, None]                       # (B, T)
    hit = hit_bt[:, :, None, None]
    ck = jnp.where(hit, k.astype(cache.k.dtype), cache.k)
    cv = jnp.where(hit, v.astype(cache.v.dtype), cache.v)
    cpos = jnp.where(hit_bt, pos_b[:, None], cache.positions)
    ck = shd.shard(ck, *cache_axes(cfg, tp))
    cv = shd.shard(cv, *cache_axes(cfg, tp))

    valid = (cpos >= 0) & (cpos <= pos_b[:, None])
    if cfg.swa_window is not None:
        valid = valid & (cpos > pos_b[:, None] - cfg.swa_window)
    out = _attend(q, ck.astype(x.dtype), cv.astype(x.dtype),
                  valid[:, None, :], exact=_exact_attend(cfg))
    out = out.reshape(B, 1, -1)
    out = shd.shard(out, "batch", None, "tp")
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return y, KVCache(k=ck, v=cv, positions=cpos)
