"""Unified transformer LM: dense / moe / vlm / audio families.

One block = preRMS -> attention -> residual -> preRMS -> FFN -> residual,
with the FFN being dense SwiGLU or MoE.  Layers are stacked (leading dim L)
and iterated with ``lax.scan`` so HLO size / compile time stay flat in depth
(roofline terms are composed per-layer, see benchmarks/roofline.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.common import PSpec, rms_norm, swiglu
from repro.runtime import sharding as shd


def _is_moe(cfg: ModelConfig) -> bool:
    return cfg.family == "moe"


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, tp: int) -> Dict[str, Any]:
    d, L = cfg.d_model, cfg.n_layers
    vp = cfg.padded_vocab(tp)
    layer: Dict[str, Any] = {
        "attn": attn.attn_specs(cfg, tp, prefix_layers=(L,)),
        "ln1": PSpec((L, d), ("layers", None), init="ones"),
        "ln2": PSpec((L, d), ("layers", None), init="ones"),
    }
    if _is_moe(cfg):
        layer["moe"] = moe_mod.moe_specs(cfg, tp, prefix_layers=(L,))
    else:
        layer["ffn"] = {
            "w_gate": PSpec((L, d, cfg.d_ff), ("layers", "fsdp", "tp")),
            "w_in": PSpec((L, d, cfg.d_ff), ("layers", "fsdp", "tp")),
            "w_out": PSpec((L, cfg.d_ff, d), ("layers", "tp", "fsdp")),
        }
    sp: Dict[str, Any] = {
        "embed": PSpec((vp, d), ("tp", "fsdp"), init="small"),
        "layers": layer,
        "final_norm": PSpec((d,), (None,), init="ones"),
    }
    if cfg.frontend != "none":
        sp["frontend_proj"] = PSpec((d, d), ("fsdp", None))
        if cfg.family == "audio":
            sp["mask_embed"] = PSpec((d,), (None,), init="small")
    if not cfg.tie_embeddings:
        sp["lm_head"] = PSpec((d, vp), ("fsdp", "tp"), init="small")
    return sp


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _block_train(cfg: ModelConfig, tp: int, prefix_len: int,
                 x: jax.Array, positions: jax.Array, lp) -> Tuple[jax.Array, jax.Array]:
    """One layer, full-sequence. Returns (x, aux_loss)."""
    h = rms_norm(x, lp["ln1"], cfg.rms_eps)
    x = x + attn.full_attention(cfg, lp["attn"], h, positions, tp, prefix_len)
    x = shd.shard(x, "batch", None, None)
    h = rms_norm(x, lp["ln2"], cfg.rms_eps)
    if _is_moe(cfg):
        y, aux = moe_mod.moe_ffn(cfg, lp["moe"], h, tp)
    else:
        f = lp["ffn"]
        y = swiglu(h, f["w_gate"], f["w_in"], f["w_out"],
                   act="gelu" if cfg.family == "vlm" else "silu")
        aux = jnp.zeros((), jnp.float32)
    x = x + y
    return shd.shard(x, "batch", None, None), aux


def _block_decode(cfg: ModelConfig, tp: int, x, pos, lp, cache):
    h = rms_norm(x, lp["ln1"], cfg.rms_eps)
    y, cache = attn.decode_attention(cfg, lp["attn"], h, pos, tp, cache)
    x = x + y
    h = rms_norm(x, lp["ln2"], cfg.rms_eps)
    if _is_moe(cfg):
        y, _ = moe_mod.moe_ffn(cfg, lp["moe"], h, tp)
    else:
        f = lp["ffn"]
        y = swiglu(h, f["w_gate"], f["w_in"], f["w_out"],
                   act="gelu" if cfg.family == "vlm" else "silu")
    return x + y, cache


def _block_prefill(cfg: ModelConfig, tp: int, prefix_len, x, positions, lp, cache):
    h = rms_norm(x, lp["ln1"], cfg.rms_eps)
    y, cache = attn.prefill_attention(cfg, lp["attn"], h, positions, tp, cache,
                                      prefix_len)
    x = x + y
    h = rms_norm(x, lp["ln2"], cfg.rms_eps)
    if _is_moe(cfg):
        y, _ = moe_mod.moe_ffn(cfg, lp["moe"], h, tp)
    else:
        f = lp["ffn"]
        y = swiglu(h, f["w_gate"], f["w_in"], f["w_out"],
                   act="gelu" if cfg.family == "vlm" else "silu")
    return x + y, cache


def _scan_layers(cfg: ModelConfig, body, x, layers, *extra):
    """Scan `body` over stacked layer params (+ optional stacked cache)."""
    if cfg.scan_layers:
        def step(carry, xs):
            lp = xs[0]
            out = body(carry, lp, *xs[1:])
            if isinstance(out, tuple):
                return out[0], out[1:]
            return out, ()
        fn = jax.checkpoint(step) if cfg.remat else step
        carry, ys = jax.lax.scan(fn, x, (layers,) + extra)
        return carry, ys
    carry = x
    ys = []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], layers)
        ex = tuple(jax.tree.map(lambda a: a[i], e) for e in extra)
        out = body(carry, lp, *ex)
        if isinstance(out, tuple):
            carry, y = out[0], out[1:]
        else:
            carry, y = out, ()
        ys.append(y)
    if ys and ys[0]:
        ys = tuple(jax.tree.map(lambda *a: jnp.stack(a), *[y[i] for y in ys])
                   for i in range(len(ys[0])))
    else:
        ys = ()
    return carry, ys


# ---------------------------------------------------------------------------
# model entry points
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, p, batch: Dict[str, jax.Array], tp: int
                 ) -> Tuple[jax.Array, int]:
    """Returns (x (B,S,d), prefix_len)."""
    d = cfg.d_model
    if cfg.family == "vlm":
        patches = batch["patch_embeds"]                  # (B, Np, d)
        front = jnp.einsum("bpd,de->bpe", patches, p["frontend_proj"])
        tok = jnp.take(p["embed"], batch["tokens"], axis=0) * (d ** 0.5)
        x = jnp.concatenate([front.astype(tok.dtype), tok], axis=1)
        return shd.shard(x, "batch", None, None), patches.shape[1]
    if cfg.family == "audio":
        frames = batch["frames"]                         # (B, S, d)
        x = jnp.einsum("bsd,de->bse", frames, p["frontend_proj"])
        if "mask" in batch:
            x = jnp.where(batch["mask"][..., None], p["mask_embed"], x)
        return shd.shard(x, "batch", None, None), 0
    x = jnp.take(p["embed"], batch["tokens"], axis=0)
    return shd.shard(x, "batch", None, None), 0


def lm_head(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.einsum("...d,dv->...v", x, w)
    return shd.shard(logits, "batch", None, "tp") if logits.ndim == 3 else \
        shd.shard(logits, "batch", "tp")


def forward_train(cfg: ModelConfig, p, batch, tp: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (features (B,S,d), aux_loss, prefix_len-as-array-free int)."""
    x, prefix_len = embed_inputs(cfg, p, batch, tp)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    body = functools.partial(_block_train, cfg, tp, prefix_len)

    def step(carry, lp):
        y, aux = body(carry, positions, lp)
        return y, aux
    x, auxes = _scan_layers(cfg, lambda c, lp: step(c, lp), x, p["layers"])
    aux = jnp.sum(auxes[0]) if auxes else jnp.zeros((), jnp.float32)
    x = rms_norm(x, p["final_norm"], cfg.rms_eps)
    return x, aux, prefix_len


def loss_fn(cfg: ModelConfig, p, batch, tp: int, loss_chunk: int = 512
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Causal-LM (or masked-prediction) loss with seq-chunked head.

    The (B, S, V) logits never materialize: the head matmul + CE run per
    seq-chunk inside a scan (vocab up to 257k at bf16 would otherwise
    dominate activation memory).
    """
    x, aux, prefix_len = forward_train(cfg, p, batch, tp)
    B, S, d = x.shape
    vp = cfg.padded_vocab(tp)

    if cfg.family == "audio":
        labels = batch["labels"]
        mask = batch["mask"].astype(jnp.float32)
    elif cfg.family == "vlm":
        tok = batch["tokens"]
        labels = jnp.pad(tok[:, 1:], ((0, 0), (0, 1)))  # next-token over text
        labels = jnp.pad(labels, ((0, 0), (prefix_len, 0)))[:, :S]
        mask = jnp.zeros((B, S), jnp.float32).at[:, prefix_len:-1].set(1.0)
    else:
        tok = batch["tokens"]
        labels = jnp.pad(tok[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones((B, S - 1), jnp.float32), ((0, 0), (0, 1)))

    C = min(loss_chunk, S)
    n = S // C
    head_w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]

    def chunk_loss(_, xs):
        xc, lc, mc = xs                                  # (B,C,d) (B,C) (B,C)
        logits = jnp.einsum("bcd,dv->bcv", xc, head_w).astype(jnp.float32)
        logits = shd.shard(logits, "batch", None, "tp")
        if vp > cfg.vocab_size:
            bias = jnp.concatenate([jnp.zeros((cfg.vocab_size,), jnp.float32),
                                    jnp.full((vp - cfg.vocab_size,), -1e9)])
            logits = logits + bias
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lc, vp, dtype=jnp.float32)
        gold = jnp.einsum("bcv,bcv->bc", logits, onehot)
        nll = (lse - gold) * mc
        return None, (jnp.sum(nll), jnp.sum(mc))

    xs = (x.reshape(B, n, C, d).transpose(1, 0, 2, 3),
          labels.reshape(B, n, C).transpose(1, 0, 2),
          mask.reshape(B, n, C).transpose(1, 0, 2))
    _, (nll_sum, m_sum) = jax.lax.scan(chunk_loss, None, xs,
                                       unroll=True if cfg.unroll_scans else 1)
    loss = jnp.sum(nll_sum) / jnp.maximum(jnp.sum(m_sum), 1.0)
    metrics = {"ce_loss": loss, "aux_loss": aux}
    return loss + aux, metrics


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, tp: int,
               dtype=jnp.bfloat16) -> attn.KVCache:
    return attn.init_cache(cfg, batch, max_len, tp, dtype, stacked=cfg.n_layers)


def serve_prefill(cfg, p, batch, tp: int, cache, last_pos=None):
    """Process the prompt; returns (last-position logits (B, V), cache).

    ``last_pos`` ((B,) int32, optional) serves *left-aligned* padded
    prompt batches: the logits are gathered at each slot's own last real
    token (position ``len - 1``) instead of the common final position,
    and cache rows written past a slot's last real token are invalidated
    (``positions = -1``) so decode never attends the right-padding.
    Causality makes the left-aligned real tokens exact: position ``j``
    only ever attends positions ``<= j``, which are all real.
    """
    x, prefix_len = embed_inputs(cfg, p, batch, tp)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(c, lp, cache_l):
        return _block_prefill(cfg, tp, prefix_len, c, positions, lp, cache_l)
    x, ys = _scan_layers(cfg, body, x, p["layers"], cache)
    new_cache = ys[0]
    x = rms_norm(x, p["final_norm"], cfg.rms_eps)
    if last_pos is None:
        return lm_head(cfg, p, x[:, -1]), new_cache
    last_pos = jnp.asarray(last_pos, jnp.int32)
    feats = x[jnp.arange(x.shape[0]), last_pos]              # (B, d)
    # drop pad rows: a cache slot holding absolute position > last_pos is
    # right-padding K/V — mark it empty so decode's validity mask (and a
    # later ring overwrite) treats it exactly like a never-written slot
    cpos = new_cache.positions                               # (L?, B, T)
    keep = (cpos >= 0) & (cpos <= last_pos[..., :, None])
    new_cache = new_cache._replace(
        positions=jnp.where(keep, cpos, -1))
    return lm_head(cfg, p, feats), new_cache


def serve_step(cfg: ModelConfig, p, tokens: jax.Array, pos: jax.Array,
               tp: int, cache) -> Tuple[jax.Array, Any]:
    """One decode step. tokens: (B,) int32; pos: scalar int32."""
    x = jnp.take(p["embed"], tokens[:, None], axis=0)
    if cfg.family == "vlm":
        x = x * (cfg.d_model ** 0.5)
    x = shd.shard(x, "batch", None, None)

    def body(c, lp, cache_l):
        return _block_decode(cfg, tp, c, pos, lp, cache_l)
    x, ys = _scan_layers(cfg, body, x, p["layers"], cache)
    new_cache = ys[0]
    x = rms_norm(x, p["final_norm"], cfg.rms_eps)
    return lm_head(cfg, p, x[:, -1]), new_cache
