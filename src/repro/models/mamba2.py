"""Mamba2 (SSD) block [arXiv:2405.21060], the Zamba2 backbone unit.

State-space recurrence per head (A scalar per head, n_groups=1):

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t (x) B_t      h: (P, N)
    y_t = C_t . h_t + D * x_t

Training uses the chunked SSD form: intra-chunk (C_t.B_s) kernel with a
masked log-space decay matrix (always <= 0 before exp: stable), plus
cross-chunk state passing.  Decode carries (conv window, h) only.

TP: d_inner = 5120 and nh = 80 both divide 16, and 320-per-device slices
align to whole SSD heads, so no padding is needed for this family.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import PSpec, rms_norm
from repro.runtime import sharding as shd


def layer_specs(cfg: ModelConfig, tp: int, L: int) -> Dict[str, Any]:
    d, s = cfg.d_model, cfg.ssm
    di = s.d_inner(d)
    nh = s.n_heads(d)
    ds = s.d_state
    lx = ("layers",)
    return {
        "w_z": PSpec((L, d, di), lx + ("fsdp", "tp")),
        "w_x": PSpec((L, d, di), lx + ("fsdp", "tp")),
        "w_B": PSpec((L, d, ds), lx + ("fsdp", None)),
        "w_C": PSpec((L, d, ds), lx + ("fsdp", None)),
        "w_dt": PSpec((L, d, nh), lx + ("fsdp", "tp")),
        "conv_x": PSpec((L, s.d_conv, di), lx + (None, "tp"), init="small"),
        "conv_B": PSpec((L, s.d_conv, ds), lx + (None, None), init="small"),
        "conv_C": PSpec((L, s.d_conv, ds), lx + (None, None), init="small"),
        "dt_bias": PSpec((L, nh), lx + ("tp",), init="zeros"),
        "A_log": PSpec((L, nh), lx + ("tp",), init="zeros"),
        "D": PSpec((L, nh), lx + ("tp",), init="ones"),
        "gn": PSpec((L, di), lx + ("tp",), init="ones"),
        "ln": PSpec((L, d), lx + (None,), init="ones"),
        "w_out": PSpec((L, di, d), lx + ("tp", "fsdp")),
    }


class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, di + 2*ds) last inputs to the causal conv
    h: jax.Array     # (B, nh, P, N) f32 SSD state


def init_state(cfg: ModelConfig, batch: int, stacked: int = 0) -> MambaState:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    lead = (stacked,) if stacked else ()
    return MambaState(
        conv=jnp.zeros(lead + (batch, s.d_conv - 1, di + 2 * s.d_state),
                       jnp.float32),
        h=jnp.zeros(lead + (batch, nh, s.head_dim, s.d_state), jnp.float32),
    )


def _causal_conv(seq: jax.Array, w: jax.Array, prev: jax.Array) -> jax.Array:
    """Depthwise causal conv. seq: (B,S,ch), w: (K,ch), prev: (B,K-1,ch)."""
    K = w.shape[0]
    full = jnp.concatenate([prev.astype(seq.dtype), seq], axis=1)
    out = jnp.zeros_like(seq)
    for i in range(K):
        out = out + full[:, i:i + seq.shape[1]] * w[i]
    return out


def _ssd_chunked(xh, Bm, Cm, da, h0, chunk, unroll: bool = False):
    """Chunked SSD.  xh: (B,S,H,P); Bm/Cm: (B,S,N); da: (B,S,H) log decay<=0;
    h0: (B,H,P,N) f32.  Returns (y (B,S,H,P), h (B,H,P,N))."""
    B, S, H, P = xh.shape
    C = min(chunk, S)
    nc = -(-S // C)
    Sp = nc * C
    if Sp != S:  # zero-pad: x=0 adds nothing to state, da=0 keeps decay 1
        xh = jnp.pad(xh, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, Sp - S), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, Sp - S), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, Sp - S), (0, 0)))
    xc = xh.reshape(B, nc, C, H, P).transpose(1, 0, 3, 2, 4)    # (nc,B,H,C,P)
    dac = da.reshape(B, nc, C, H).transpose(1, 0, 3, 2)         # (nc,B,H,C)
    Bc = Bm.reshape(B, nc, C, -1).transpose(1, 0, 2, 3)         # (nc,B,C,N)
    Cc = Cm.reshape(B, nc, C, -1).transpose(1, 0, 2, 3)

    mask = jnp.tril(jnp.ones((C, C), bool))                     # s <= t

    def step(h, xs):
        x_, da_, B_, C_ = xs
        x_ = x_.astype(jnp.float32)
        B_, C_ = B_.astype(jnp.float32), C_.astype(jnp.float32)
        cum = jnp.cumsum(da_, axis=-1)                          # (B,H,C)
        # cross-chunk
        y = jnp.einsum("btn,bhpn,bht->bhtp", C_, h, jnp.exp(cum))
        # intra-chunk
        g = jnp.einsum("btn,bsn->bts", C_, B_)                  # (B,C,C)
        diff = cum[:, :, :, None] - cum[:, :, None, :]          # (B,H,t,s)
        ldec = jnp.where(mask[None, None], jnp.exp(diff), 0.0)
        y = y + jnp.einsum("bts,bhts,bhsp->bhtp", g, ldec, x_)
        # state update
        dtot = jnp.exp(cum[:, :, -1])                           # (B,H)
        kdec = jnp.exp(cum[:, :, -1:] - cum)                    # (B,H,C)
        h = dtot[..., None, None] * h + \
            jnp.einsum("bhs,bhsp,bsn->bhpn", kdec, x_, B_)
        return h, y

    h, ys = jax.lax.scan(step, h0, (xc, dac, Bc, Cc),
                         unroll=True if unroll else 1)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, Sp, H, P)[:, :S]
    return y, h


def block(cfg: ModelConfig, lp, x: jax.Array, state: MambaState, tp: int,
          single_token: bool) -> Tuple[jax.Array, MambaState]:
    """One Mamba2 block with residual. x: (B,S,d)."""
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    P, N = s.head_dim, s.d_state
    B_, S_, _ = x.shape

    xn = rms_norm(x, lp["ln"], cfg.rms_eps)
    z = jnp.einsum("bsd,de->bse", xn, lp["w_z"])
    xi = jnp.einsum("bsd,de->bse", xn, lp["w_x"])
    Bm = jnp.einsum("bsd,dn->bsn", xn, lp["w_B"])
    Cm = jnp.einsum("bsd,dn->bsn", xn, lp["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", xn, lp["w_dt"])
    xi = shd.shard(xi, "batch", None, "tp")

    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)
    conv_w = jnp.concatenate([lp["conv_x"], lp["conv_B"], lp["conv_C"]],
                             axis=-1)
    if single_token:
        window = jnp.concatenate(
            [state.conv.astype(conv_in.dtype), conv_in], axis=1)
        conv_out = jnp.einsum("bkc,kc->bc", window, conv_w)[:, None]
        new_conv = window[:, 1:].astype(jnp.float32)
    else:
        conv_out = _causal_conv(conv_in, conv_w, state.conv)
        new_conv = conv_in[:, -(s.d_conv - 1):].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out)
    xi, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    da = dt * A                                                 # (B,S,H) <= 0
    xh = (xi * 1.0).reshape(B_, S_, nh, P)
    xdt = xh.astype(jnp.float32) * dt[..., None]

    if single_token:
        # h' = exp(da) h + dt x (x) B ; y = C.h' + D x
        h = jnp.exp(da[:, 0])[..., None, None] * state.h + \
            jnp.einsum("bhp,bn->bhpn", xdt[:, 0], Bm[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)[:, None]
        y = y.reshape(B_, 1, nh, P)
    else:
        y, h = _ssd_chunked(xdt, Bm, Cm, da, state.h, s.chunk,
                            unroll=cfg.unroll_scans)

    y = y + xh.astype(jnp.float32) * lp["D"][None, None, :, None]
    y = y.reshape(B_, S_, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, lp["gn"], cfg.rms_eps)
    y = shd.shard(y, "batch", None, "tp")
    out = jnp.einsum("bse,ed->bsd", y, lp["w_out"])
    return shd.shard(x + out, "batch", None, None), MambaState(conv=new_conv, h=h)
