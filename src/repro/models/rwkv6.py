"""RWKV-6 (Finch): data-dependent decay linear RNN [arXiv:2404.05892].

Structure per layer: time-mix (WKV6 recurrence) + channel-mix, both with
token-shift and the ddlerp dynamic mixing LoRA.  Recurrence per head:

    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]
    y_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])

with w_t = exp(-exp(decay_t)) data-dependent per channel.  Training uses a
chunk-parallel form (intra-chunk decay matrix in log space + cross-chunk
state passing); decode carries (shift tokens, WKV state) only, so context
length is unbounded — this is why rwkv6 runs the ``long_500k`` cell.

TP note: 40 heads don't divide the 16-way model axis, so heads are padded to
48 (zero in/out projections — wasted FLOPs visible in the roofline ratio).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import PSpec, rms_norm
from repro.runtime import sharding as shd

# WKV6 chunk length: the intra-chunk pairwise-decay tensor is
# (B, H, C, C, N) f32, so C is the main activation-memory lever.
CHUNK = 32


def _round_up(x, m):
    return ((x + m - 1) // m) * m


def padded_rwkv_heads(cfg: ModelConfig, tp: int) -> int:
    return _round_up(cfg.d_model // cfg.rwkv.head_size, tp) if tp > 1 else \
        cfg.d_model // cfg.rwkv.head_size


def layer_specs(cfg: ModelConfig, tp: int, L: int) -> Dict[str, Any]:
    d, r = cfg.d_model, cfg.rwkv
    hp = padded_rwkv_heads(cfg, tp)
    da = hp * r.head_size  # padded attention width
    lx = ("layers",)
    return {
        # time-mix
        "mu_x": PSpec((L, d), lx + (None,), init="small"),
        "mu": PSpec((L, 5, d), lx + (None, None), init="small"),
        "mix_w1": PSpec((L, d, 5 * r.mix_lora), lx + ("fsdp", None), init="small"),
        "mix_w2": PSpec((L, 5, r.mix_lora, d), lx + (None, None, None), init="small"),
        "wr": PSpec((L, d, da), lx + ("fsdp", "tp")),
        "wk": PSpec((L, d, da), lx + ("fsdp", "tp")),
        "wv": PSpec((L, d, da), lx + ("fsdp", "tp")),
        "wg": PSpec((L, d, da), lx + ("fsdp", "tp")),
        "decay_mu": PSpec((L, da), lx + ("tp",), init="zeros"),
        "dec_w1": PSpec((L, d, r.decay_lora), lx + ("fsdp", None), init="small"),
        "dec_w2": PSpec((L, r.decay_lora, da), lx + (None, "tp"), init="small"),
        "u": PSpec((L, da), lx + ("tp",), init="small"),
        "wo": PSpec((L, da, d), lx + ("tp", "fsdp")),
        "gn": PSpec((L, da), lx + ("tp",), init="ones"),
        "ln1": PSpec((L, d), lx + (None,), init="ones"),
        # channel-mix
        "c_mu_k": PSpec((L, d), lx + (None,), init="small"),
        "c_mu_r": PSpec((L, d), lx + (None,), init="small"),
        "wck": PSpec((L, d, cfg.d_ff), lx + ("fsdp", "tp")),
        "wcv": PSpec((L, cfg.d_ff, d), lx + ("tp", "fsdp")),
        "wcr": PSpec((L, d, d), lx + ("fsdp", None)),
        "ln2": PSpec((L, d), lx + (None,), init="ones"),
    }


class RWKVState(NamedTuple):
    tshift: jax.Array   # (B, d) last token fed to time-mix
    cshift: jax.Array   # (B, d) last token fed to channel-mix
    wkv: jax.Array      # (B, Hp, N, N) f32 state


def init_state(cfg: ModelConfig, batch: int, tp: int, stacked: int = 0
               ) -> RWKVState:
    hp = padded_rwkv_heads(cfg, tp)
    n = cfg.rwkv.head_size
    lead = (stacked,) if stacked else ()
    return RWKVState(
        tshift=jnp.zeros(lead + (batch, cfg.d_model), jnp.float32),
        cshift=jnp.zeros(lead + (batch, cfg.d_model), jnp.float32),
        wkv=jnp.zeros(lead + (batch, hp, n, n), jnp.float32),
    )


def _ddlerp(lp, x, xprev):
    """Dynamic token-shift mixing -> the 5 mixed inputs (r,k,v,g,w)."""
    delta = xprev - x
    xxx = x + delta * lp["mu_x"]
    lora = jnp.tanh(jnp.einsum("...d,dm->...m", xxx, lp["mix_w1"]))
    lora = lora.reshape(*lora.shape[:-1], 5, -1)
    dyn = jnp.einsum("...km,kmd->...kd", lora, lp["mix_w2"])  # (...,5,d)
    mixed = x[..., None, :] + delta[..., None, :] * (lp["mu"] + dyn)
    return [mixed[..., i, :] for i in range(5)]


def _tmix_projections(cfg, lp, x, xprev, tp):
    """Returns r,k,v,g: (B,S,Hp,N); logw: (B,S,Hp,N) (log decay <= 0)."""
    n = cfg.rwkv.head_size
    xr, xk, xv, xg, xw = _ddlerp(lp, x, xprev)
    r = jnp.einsum("bsd,da->bsa", xr, lp["wr"])
    k = jnp.einsum("bsd,da->bsa", xk, lp["wk"])
    v = jnp.einsum("bsd,da->bsa", xv, lp["wv"])
    g = jnp.einsum("bsd,da->bsa", xg, lp["wg"])
    dec = lp["decay_mu"] + jnp.einsum(
        "bsd,dm,ma->bsa", xw, lp["dec_w1"], lp["dec_w2"])
    logw = -jnp.exp(dec.astype(jnp.float32))  # log w_t in (-inf, 0)
    shp = (*r.shape[:-1], -1, n)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            g, logw.reshape(shp))


def _wkv_chunked(r, k, v, logw, u, state, unroll: bool = False):
    """Chunk-parallel WKV6.  r/k/v/logw: (B,S,H,N) with S % CHUNK == 0.
    state: (B,H,N,N) f32.  Returns (y (B,S,H,N), new state).
    """
    B, S, H, N = r.shape
    C = min(CHUNK, S)
    nc = -(-S // C)
    Sp = nc * C
    if Sp != S:  # zero-pad: k=0 adds nothing to state, logw=0 keeps decay 1
        pad = lambda a: jnp.pad(a, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        r, k, v, logw = pad(r), pad(k), pad(v), pad(logw)
    rs = lambda a: a.reshape(B, nc, C, H, N).transpose(1, 0, 3, 2, 4)
    r, k, v, logw = map(rs, (r, k, v, logw))          # (nc,B,H,C,N)
    r, k, v = r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    uu = u.reshape(H, N)

    def chunk(state, xs):
        rc, kc, vc, lw = xs                            # (B,H,C,N)
        cum = jnp.cumsum(lw, axis=2)                   # inclusive logs
        cum_prev = cum - lw                            # exclusive
        # cross-chunk: y_x[t] = (r_t * exp(cum_prev_t)) @ S0   (exp <= 1: safe)
        rdec = rc * jnp.exp(cum_prev)
        y = jnp.einsum("bhti,bhij->bhtj", rdec, state)
        # intra-chunk: A[t,s] = sum_i r_t[i] k_s[i] exp(cum_prev_t - cum_s)[i]
        # The difference is <= 0 for s < t, so exponentiate the *pairwise*
        # log-space tensor (factorizing into exp(cum_prev_t)*exp(-cum_s)
        # overflows for strong decays).
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
        diff = cum_prev[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,H,t,s,N)
        diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
        att = jnp.einsum("bhti,bhsi,bhtsi->bhts", rc, kc, jnp.exp(diff))
        diag = jnp.einsum("bhti,bhti->bht", rc, kc * uu[None, :, None, :])
        y = y + jnp.einsum("bhts,bhsj->bhtj", att, vc) + diag[..., None] * vc
        # state update: S' = exp(cum_C) S0 + sum_s exp(cum_C - cum_s) k_s v_s
        dtot = jnp.exp(cum[:, :, -1:, :])              # (B,H,1,N)
        kdec = kc * jnp.exp(cum[:, :, -1:, :] - cum)
        state = dtot.squeeze(2)[..., None] * state + \
            jnp.einsum("bhsi,bhsj->bhij", kdec, vc)
        return state, y

    state, ys = jax.lax.scan(chunk, state, (r, k, v, logw),
                             unroll=True if unroll else 1)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, Sp, H, N)[:, :S]
    return y, state


def _wkv_step(r, k, v, logw, u, state):
    """Single-token WKV. r/k/v/logw: (B,H,N); state (B,H,N,N)."""
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    uu = u.reshape(*u.shape[:-1], -1) if u.ndim == 1 else u
    kv = k[..., :, None] * v[..., None, :]             # (B,H,N,N)
    y = jnp.einsum("bhi,bhij->bhj", r, state + uu[None, :, :, None] * kv)
    state = jnp.exp(logw)[..., None] * state + kv
    return y, state


def _group_norm(y, gamma, eps=1e-5):
    """Per-head normalization. y: (..., H, N)."""
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    return (y - mu) * jax.lax.rsqrt(var + eps) * gamma


def time_mix(cfg: ModelConfig, lp, x, state: RWKVState, tp: int,
             single_token: bool) -> Tuple[jax.Array, RWKVState]:
    B = x.shape[0]
    n = cfg.rwkv.head_size
    hp = padded_rwkv_heads(cfg, tp)
    xn = rms_norm(x, lp["ln1"], cfg.rms_eps)
    if single_token:
        xprev = state.tshift[:, None, :].astype(xn.dtype)
    else:
        xprev = jnp.concatenate(
            [state.tshift[:, None, :].astype(xn.dtype), xn[:, :-1]], axis=1)
    r, k, v, g, logw = _tmix_projections(cfg, lp, xn, xprev, tp)
    if single_token:
        y, wkv = _wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0],
                           lp["u"].reshape(hp, n), state.wkv)
        y = y[:, None]
    else:
        y, wkv = _wkv_chunked(r, k, v, logw, lp["u"].reshape(hp, n), state.wkv,
                              unroll=cfg.unroll_scans)
    y = _group_norm(y, lp["gn"].reshape(hp, n)).astype(x.dtype)
    y = y.reshape(*y.shape[:-2], hp * n) * jax.nn.silu(g)
    y = shd.shard(y, "batch", None, "tp")
    out = jnp.einsum("bsa,ad->bsd", y, lp["wo"])
    new_state = RWKVState(tshift=xn[:, -1].astype(jnp.float32),
                          cshift=state.cshift, wkv=wkv)
    return out, new_state


def channel_mix(cfg: ModelConfig, lp, x, state: RWKVState, tp: int,
                single_token: bool) -> Tuple[jax.Array, RWKVState]:
    xn = rms_norm(x, lp["ln2"], cfg.rms_eps)
    if single_token:
        xprev = state.cshift[:, None, :].astype(xn.dtype)
    else:
        xprev = jnp.concatenate(
            [state.cshift[:, None, :].astype(xn.dtype), xn[:, :-1]], axis=1)
    delta = xprev - xn
    xk = xn + delta * lp["c_mu_k"]
    xr = xn + delta * lp["c_mu_r"]
    kh = jnp.einsum("bsd,df->bsf", xk, lp["wck"])
    kh = shd.shard(jnp.square(jax.nn.relu(kh)), "batch", None, "tp")
    kv = jnp.einsum("bsf,fd->bsd", kh, lp["wcv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, lp["wcr"]))
    new_state = RWKVState(tshift=state.tshift,
                          cshift=xn[:, -1].astype(jnp.float32), wkv=state.wkv)
    return rr * kv, new_state


def block(cfg: ModelConfig, lp, x, state: RWKVState, tp: int,
          single_token: bool) -> Tuple[jax.Array, RWKVState]:
    y, state = time_mix(cfg, lp, x, state, tp, single_token)
    x = shd.shard(x + y, "batch", None, None)
    y, state = channel_mix(cfg, lp, x, state, tp, single_token)
    return shd.shard(x + y, "batch", None, None), state
