"""The paper's client models (FedAT §6.1).

  * CIFAR-10 / Fashion-MNIST CNN: conv(32) -> conv(64) -> conv(64) ->
    dense(64) -> dense(n_classes), each conv followed by 2x2 max-pool.
  * Sentiment140: logistic regression (convex objective).

Pure-JAX functional models: params are dicts, ``apply`` maps
(params, x) -> logits.  Used by the federated simulation (clients train
these locally) and by the paper-table benchmarks.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def _conv(x, w, b):
    """SAME-padded stride-1 conv as im2col + matmul (odd kernels only).

    The federated simulation vmaps this over clients with *per-client*
    weights; as a convolution that lowers to grouped conv, which XLA CPU
    executes on a slow path at these tiny spatial sizes (8x8 and down).
    Patch-extraction + ``@`` lowers to a batched GEMM instead — ~3x
    faster end-to-end for the vmapped client update, and TPU lowers the
    same contraction to the MXU.
    """
    B, H, W, C = x.shape
    kh, kw, _, O = w.shape
    assert kh % 2 == 1 and kw % 2 == 1, "im2col conv assumes odd kernels"
    xp = jnp.pad(x, ((0, 0), (kh // 2, kh // 2), (kw // 2, kw // 2), (0, 0)))
    patches = jnp.concatenate(
        [xp[:, i:i + H, j:j + W, :] for i in range(kh) for j in range(kw)],
        axis=-1)                                          # (B, H, W, kh*kw*C)
    y = patches.reshape(B, H * W, kh * kw * C) @ w.reshape(kh * kw * C, O)
    return y.reshape(B, H, W, O) + b


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_init(key: jax.Array, in_shape: Tuple[int, int, int] = (32, 32, 3),
             n_classes: int = 10) -> Dict[str, jax.Array]:
    h, w, c = in_shape
    ks = jax.random.split(key, 5)

    def he(k, shape, fan_in):
        return jax.random.normal(k, shape) * math.sqrt(2.0 / fan_in)

    p = {
        "c1_w": he(ks[0], (3, 3, c, 32), 9 * c), "c1_b": jnp.zeros((32,)),
        "c2_w": he(ks[1], (3, 3, 32, 64), 9 * 32), "c2_b": jnp.zeros((64,)),
        "c3_w": he(ks[2], (3, 3, 64, 64), 9 * 64), "c3_b": jnp.zeros((64,)),
    }
    hh, ww = h // 8, w // 8  # three 2x2 pools
    flat = hh * ww * 64
    p["d1_w"] = he(ks[3], (flat, 64), flat)
    p["d1_b"] = jnp.zeros((64,))
    p["d2_w"] = he(ks[4], (64, n_classes), 64)
    p["d2_b"] = jnp.zeros((n_classes,))
    return p


def cnn_apply(p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """x: (B, H, W, C) float -> logits (B, n_classes)."""
    x = _maxpool(jax.nn.relu(_conv(x, p["c1_w"], p["c1_b"])))
    x = _maxpool(jax.nn.relu(_conv(x, p["c2_w"], p["c2_b"])))
    x = _maxpool(jax.nn.relu(_conv(x, p["c3_w"], p["c3_b"])))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["d1_w"] + p["d1_b"])
    return x @ p["d2_w"] + p["d2_b"]


def logreg_init(key: jax.Array, n_features: int, n_classes: int = 2
                ) -> Dict[str, jax.Array]:
    return {
        "w": jax.random.normal(key, (n_features, n_classes)) * 0.01,
        "b": jnp.zeros((n_classes,)),
    }


def logreg_apply(p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """x: (B, F) -> logits (B, n_classes). Convex objective."""
    return x @ p["w"] + p["b"]


def make_model(kind: str, key: jax.Array, **kw):
    """Returns (params, apply_fn)."""
    if kind == "cnn":
        return cnn_init(key, **kw), cnn_apply
    if kind == "logreg":
        return logreg_init(key, **kw), logreg_apply
    raise ValueError(kind)


def ce_loss(apply_fn, params, batch) -> jax.Array:
    logits = apply_fn(params, batch["x"])
    labels = jax.nn.one_hot(batch["y"], logits.shape[-1])
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(labels * logp, axis=-1))


def accuracy(apply_fn, params, x, y) -> jax.Array:
    return jnp.mean(jnp.argmax(apply_fn(params, x), axis=-1) == y)
