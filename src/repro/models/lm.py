"""Unified LM facade: one API over all assigned architectures.

  * ``param_specs / init_params / param_axes``
  * ``loss_fn(cfg, params, batch, tp)``          (train shapes)
  * ``serve_prefill(cfg, params, batch, tp, cache)``
  * ``serve_step(cfg, params, tokens, pos, tp, cache)``
  * ``init_cache / abstract_cache / cache_axes_tree``
  * ``input_specs(cfg, shape)``                  (ShapeDtypeStruct stand-ins)

Families: dense/vlm/audio/moe -> transformer.py; ssm -> rwkv6.py;
hybrid -> zamba2.py.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.models import attention as attn
from repro.models import common, rwkv6, transformer, zamba2
from repro.models import mamba2
from repro.models.common import PSpec, rms_norm
from repro.runtime import sharding as shd

TRANSFORMER_FAMILIES = ("dense", "moe", "vlm", "audio")


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, tp: int) -> Dict[str, Any]:
    if cfg.family in TRANSFORMER_FAMILIES:
        return transformer.param_specs(cfg, tp)
    if cfg.family == "hybrid":
        return zamba2.param_specs(cfg, tp)
    if cfg.family == "ssm":
        vp = cfg.padded_vocab(tp)
        d = cfg.d_model
        return {
            "embed": PSpec((vp, d), ("tp", "fsdp"), init="small"),
            "layers": rwkv6.layer_specs(cfg, tp, cfg.n_layers),
            "final_norm": PSpec((d,), (None,), init="ones"),
            "lm_head": PSpec((d, vp), ("fsdp", "tp"), init="small"),
        }
    raise ValueError(cfg.family)


def init_params(cfg: ModelConfig, key: jax.Array, tp: int, dtype=jnp.float32):
    return common.init_from_specs(param_specs(cfg, tp), key, dtype)


def param_axes(cfg: ModelConfig, tp: int):
    return common.axes_from_specs(param_specs(cfg, tp))


def anchor_params(cfg: ModelConfig, params, tp: int):
    """Pin every param leaf to its logical sharding *inside* the jitted fn.

    Without this anchor GSPMD may hoist the FSDP un-shard of the stacked
    layer weights out of the scan-over-layers loop — materializing all L
    layers' gathered weights at once (13.7 GiB for qwen1.5-110b) instead of
    one layer at a time.
    """
    axes = param_axes(cfg, tp)
    return jax.tree.map(
        lambda x, a: shd.shard(x, *a), params, axes,
        is_leaf=lambda l: isinstance(l, (jax.Array, jax.ShapeDtypeStruct)))


def abstract_params(cfg: ModelConfig, tp: int, dtype=jnp.bfloat16):
    return common.shapes_from_specs(param_specs(cfg, tp), dtype)


# ---------------------------------------------------------------------------
# rwkv model-level glue (transformer/zamba have their own modules)
# ---------------------------------------------------------------------------

def _rwkv_forward(cfg, p, tokens, state, tp, single_token):
    x = jnp.take(p["embed"], tokens, axis=0)
    x = shd.shard(x, "batch", None, None)

    def body(carry, xs):
        lp, st = xs
        y, st = rwkv6.block(cfg, lp, carry, st, tp, single_token)
        return y, st
    fn = jax.checkpoint(body) if cfg.remat else body
    x, new_state = jax.lax.scan(fn, x, (p["layers"], state))
    x = rms_norm(x, p["final_norm"], cfg.rms_eps)
    return x, new_state


def _rwkv_loss(cfg, p, batch, tp):
    tokens = batch["tokens"]
    state = rwkv6.init_state(cfg, tokens.shape[0], tp, stacked=cfg.n_layers)
    x, _ = _rwkv_forward(cfg, p, tokens, state, tp, False)
    return zamba2._chunked_ce(cfg, x, p["lm_head"], tokens, tp)


def _rwkv_prefill(cfg, p, batch, tp, state):
    tokens = batch["tokens"]
    x, new_state = _rwkv_forward(cfg, p, tokens, state, tp, False)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], p["lm_head"])
    return shd.shard(logits, "batch", "tp"), new_state


def _rwkv_step(cfg, p, tokens, pos, tp, state):
    del pos  # stateful: position-free
    x, new_state = _rwkv_forward(cfg, p, tokens[:, None], state, tp, True)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], p["lm_head"])
    return shd.shard(logits, "batch", "tp"), new_state


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, p, batch, tp: int):
    if cfg.family in TRANSFORMER_FAMILIES:
        return transformer.loss_fn(cfg, p, batch, tp)
    if cfg.family == "hybrid":
        return zamba2.loss_fn(cfg, p, batch, tp)
    return _rwkv_loss(cfg, p, batch, tp)


def serve_prefill(cfg: ModelConfig, p, batch, tp: int, cache,
                  last_pos=None):
    """``last_pos`` ((B,) int32) enables exact left-aligned padded prompt
    batches — attention-only families: recurrent state (ssm/hybrid)
    integrates right-padding, so those families must feed prompts
    token-by-token instead (repro.serve.engine does)."""
    if cfg.family in TRANSFORMER_FAMILIES:
        return transformer.serve_prefill(cfg, p, batch, tp, cache,
                                         last_pos=last_pos)
    if last_pos is not None:
        raise ValueError(
            f"per-slot prefill (last_pos) is only exact for attention "
            f"families {TRANSFORMER_FAMILIES}; family {cfg.family!r} "
            f"carries recurrent state that would integrate the padding — "
            f"feed prompts through serve_step instead")
    if cfg.family == "hybrid":
        return zamba2.serve_prefill(cfg, p, batch, tp, cache)
    return _rwkv_prefill(cfg, p, batch, tp, cache)


def serve_step(cfg: ModelConfig, p, tokens, pos, tp: int, cache):
    if cfg.family in TRANSFORMER_FAMILIES:
        return transformer.serve_step(cfg, p, tokens, pos, tp, cache)
    if cfg.family == "hybrid":
        return zamba2.serve_step(cfg, p, tokens, pos, tp, cache)
    return _rwkv_step(cfg, p, tokens, pos, tp, cache)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, tp: int,
               dtype=jnp.bfloat16):
    if cfg.family in TRANSFORMER_FAMILIES:
        return transformer.init_cache(cfg, batch, max_len, tp, dtype)
    if cfg.family == "hybrid":
        return zamba2.init_cache(cfg, batch, max_len, tp, dtype)
    return rwkv6.init_state(cfg, batch, tp, stacked=cfg.n_layers)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, tp: int,
                   dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, tp, dtype))


def cache_axes_tree(cfg: ModelConfig, tp: int):
    """Logical-axes tree matching the cache structure."""
    kv_axes = (None,) + attn.cache_axes(cfg, tp)
    kv_tree = attn.KVCache(k=kv_axes, v=kv_axes,
                           positions=(None, "cache_batch", kv_axes[2]))
    if cfg.family in TRANSFORMER_FAMILIES:
        return kv_tree
    if cfg.family == "hybrid":
        return zamba2.ZambaCache(
            mamba=mamba2.MambaState(
                conv=(None, "cache_batch", None, None),
                h=(None, "cache_batch", "tp", None, None)),
            kv=kv_tree,
        )
    return rwkv6.RWKVState(
        tshift=(None, "cache_batch", None),
        cshift=(None, "cache_batch", None),
        wkv=(None, "cache_batch", "tp", None, None),
    )


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins for the dry-run / launchers)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract model inputs for one (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            np_ = min(cfg.n_frontend_tokens, S // 2)
            return {
                "patch_embeds": jax.ShapeDtypeStruct((B, np_, cfg.d_model),
                                                     jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S - np_), i32),
            }
        if cfg.family == "audio":
            out = {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                  jnp.bfloat16)}
            if shape.kind == "train":
                out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
                out["mask"] = jax.ShapeDtypeStruct((B, S), jnp.bool_)
            return out
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a cache of S
    return {
        "tokens": jax.ShapeDtypeStruct((B,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def input_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Tuple]:
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            return {"patch_embeds": ("batch", None, None),
                    "tokens": ("batch", None)}
        if cfg.family == "audio":
            out = {"frames": ("batch", None, None)}
            if shape.kind == "train":
                out["labels"] = ("batch", None)
                out["mask"] = ("batch", None)
            return out
        return {"tokens": ("batch", None)}
    return {"tokens": ("batch",), "pos": ()}
