"""Zamba2 hybrid: Mamba2 backbone + ONE shared attention block applied every
``attn_every`` layers with the same weights (Zamba2's parameter sharing).

The backbone scans over groups of ``attn_every`` Mamba2 layers; between
groups the shared full-attention (+SwiGLU) block runs unrolled (its params
are shared, so HLO stays small).  Decode carries per-layer Mamba states plus
one KV cache per shared-block application point.

Simplifications vs. the released checkpoints (recorded in DESIGN.md):
the shared block consumes the running stream x rather than concat(x, x_emb),
and per-application LoRA deltas on the shared weights are omitted.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2
from repro.models.common import PSpec, rms_norm, swiglu
from repro.runtime import sharding as shd


def n_attn_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def param_specs(cfg: ModelConfig, tp: int) -> Dict[str, Any]:
    d, L = cfg.d_model, cfg.n_layers
    vp = cfg.padded_vocab(tp)
    return {
        "embed": PSpec((vp, d), ("tp", "fsdp"), init="small"),
        "backbone": mamba2.layer_specs(cfg, tp, L),
        "shared": {
            "attn": attn.attn_specs(cfg, tp),
            "ln1": PSpec((d,), (None,), init="ones"),
            "ln2": PSpec((d,), (None,), init="ones"),
            "ffn": {
                "w_gate": PSpec((d, cfg.d_ff), ("fsdp", "tp")),
                "w_in": PSpec((d, cfg.d_ff), ("fsdp", "tp")),
                "w_out": PSpec((cfg.d_ff, d), ("tp", "fsdp")),
            },
        },
        "final_norm": PSpec((d,), (None,), init="ones"),
        "lm_head": PSpec((d, vp), ("fsdp", "tp"), init="small"),
    }


class ZambaCache(NamedTuple):
    mamba: mamba2.MambaState      # stacked (L, ...)
    kv: attn.KVCache              # stacked (n_apps, ...)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, tp: int,
               dtype=jnp.bfloat16) -> ZambaCache:
    return ZambaCache(
        mamba=mamba2.init_state(cfg, batch, stacked=cfg.n_layers),
        kv=attn.init_cache(cfg, batch, max_len, tp, dtype,
                           stacked=n_attn_apps(cfg)),
    )


def _shared_block(cfg, sp, x, positions, tp, mode, kv_cache=None, pos=None):
    h = rms_norm(x, sp["ln1"], cfg.rms_eps)
    if mode == "train":
        y = attn.full_attention(cfg, sp["attn"], h, positions, tp)
        new_cache = None
    elif mode == "prefill":
        y, new_cache = attn.prefill_attention(cfg, sp["attn"], h, positions,
                                              tp, kv_cache)
    else:
        y, new_cache = attn.decode_attention(cfg, sp["attn"], h, pos, tp,
                                             kv_cache)
    x = x + y
    h = rms_norm(x, sp["ln2"], cfg.rms_eps)
    f = sp["ffn"]
    x = x + swiglu(h, f["w_gate"], f["w_in"], f["w_out"])
    return shd.shard(x, "batch", None, None), new_cache


def _run(cfg: ModelConfig, p, x, tp: int, mode: str,
         cache: ZambaCache = None, pos=None):
    """Shared forward over modes. x: (B,S,d). Returns (x, new_cache)."""
    S = x.shape[1]
    every = cfg.attn_every
    napps = n_attn_apps(cfg)
    positions = jnp.arange(S, dtype=jnp.int32) if mode != "decode" else None
    single = mode == "decode"

    # reshape stacked backbone params/state (L, ...) -> (napps, every, ...)
    grp = lambda t: jax.tree.map(
        lambda a: a.reshape(napps, every, *a.shape[1:]), t)
    backbone = grp(p["backbone"])
    mstates = grp(cache.mamba) if cache is not None else grp(
        mamba2.init_state(cfg, x.shape[0], stacked=cfg.n_layers))

    def mamba_step(carry, xs):
        lp, st = xs
        y, st = mamba2.block(cfg, lp, carry, st, tp, single)
        return y, st
    mamba_step = jax.checkpoint(mamba_step) if cfg.remat else mamba_step

    new_mstates, new_kvs = [], []
    for g in range(napps):
        grp_params = jax.tree.map(lambda a: a[g], backbone)
        grp_state = jax.tree.map(lambda a: a[g], mstates)
        x, st = jax.lax.scan(mamba_step, x, (grp_params, grp_state))
        new_mstates.append(st)
        kv_g = jax.tree.map(lambda a: a[g], cache.kv) if cache is not None \
            else None
        kv_g = attn.KVCache(*kv_g) if kv_g is not None else None
        x, kv_new = _shared_block(cfg, p["shared"], x, positions, tp, mode,
                                  kv_g, pos)
        new_kvs.append(kv_new)

    new_cache = None
    if mode != "train":
        # each group state is (every, ...) -> concat to (L, ...)
        mstacked = jax.tree.map(lambda *a: jnp.concatenate(a, axis=0),
                                *new_mstates)
        kvstacked = jax.tree.map(lambda *a: jnp.stack(a, axis=0), *new_kvs)
        new_cache = ZambaCache(mamba=mstacked, kv=kvstacked)
    return x, new_cache


def loss_fn(cfg: ModelConfig, p, batch, tp: int):
    tokens = batch["tokens"]
    x = jnp.take(p["embed"], tokens, axis=0)
    x = shd.shard(x, "batch", None, None)
    x, _ = _run(cfg, p, x, tp, "train")
    x = rms_norm(x, p["final_norm"], cfg.rms_eps)
    return _chunked_ce(cfg, x, p["lm_head"], tokens, tp)


def _chunked_ce(cfg, x, head_w, tokens, tp, loss_chunk: int = 512):
    B, S, d = x.shape
    vp = cfg.padded_vocab(tp)
    labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = jnp.pad(jnp.ones((B, S - 1), jnp.float32), ((0, 0), (0, 1)))
    C = min(loss_chunk, S)
    n = S // C

    def chunk_loss(_, xs):
        xc, lc, mc = xs
        logits = jnp.einsum("bcd,dv->bcv", xc, head_w).astype(jnp.float32)
        logits = shd.shard(logits, "batch", None, "tp")
        if vp > cfg.vocab_size:
            bias = jnp.concatenate([jnp.zeros((cfg.vocab_size,), jnp.float32),
                                    jnp.full((vp - cfg.vocab_size,), -1e9)])
            logits = logits + bias
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lc, vp, dtype=jnp.float32)
        gold = jnp.einsum("bcv,bcv->bc", logits, onehot)
        return None, (jnp.sum((lse - gold) * mc), jnp.sum(mc))

    xs = (x.reshape(B, n, C, d).transpose(1, 0, 2, 3),
          labels.reshape(B, n, C).transpose(1, 0, 2),
          mask.reshape(B, n, C).transpose(1, 0, 2))
    _, (nll, m) = jax.lax.scan(chunk_loss, None, xs,
                               unroll=True if cfg.unroll_scans else 1)
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(m), 1.0)
    return loss, {"ce_loss": loss}


def serve_prefill(cfg: ModelConfig, p, batch, tp: int, cache: ZambaCache):
    x = jnp.take(p["embed"], batch["tokens"], axis=0)
    x = shd.shard(x, "batch", None, None)
    x, new_cache = _run(cfg, p, x, tp, "prefill", cache)
    x = rms_norm(x, p["final_norm"], cfg.rms_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], p["lm_head"])
    return shd.shard(logits, "batch", "tp"), new_cache


def serve_step(cfg: ModelConfig, p, tokens, pos, tp: int, cache: ZambaCache):
    x = jnp.take(p["embed"], tokens[:, None], axis=0)
    x = shd.shard(x, "batch", None, None)
    x, new_cache = _run(cfg, p, x, tp, "decode", cache, pos=pos)
    x = rms_norm(x, p["final_norm"], cfg.rms_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], p["lm_head"])
    return shd.shard(logits, "batch", "tp"), new_cache
