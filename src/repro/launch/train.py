"""Training driver: fault-tolerant, checkpointed, FedAT-aware.

Runs on whatever devices exist (CPU smoke -> TPU pods).  On a multi-pod
mesh each pod is a FedAT tier: the driver owns the event-driven cadence
(tiers step at their own measured pace; the compiled step handles the
compressed cross-tier aggregation), profiles per-step latency for the
straggler module, checkpoints asynchronously, and restarts from the last
good checkpoint on failure.

Example (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig
from repro.configs.registry import get_config, get_smoke_config
from repro.configs.shapes import SHAPES, ShapeConfig, smoke_shape
from repro.core import steps as steps_mod
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.runtime import sharding as shd
from repro.runtime.fault import GuardedRunner

log = logging.getLogger("repro.train")


def build(cfg, tcfg, mesh, multi_pod: bool):
    with mesh, shd.use_mesh(mesh):
        if multi_pod:
            return steps_mod.make_fedat_step(cfg, tcfg, mesh)
        return steps_mod.make_single_pod_step(cfg, tcfg, mesh)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure-rate", type=float, default=0.0)
    ap.add_argument("--fedat-sync-every", type=int, default=4)
    ap.add_argument("--fedat-bits", type=int, default=8)
    ap.add_argument("--codec", default=None,
                    help="transport codec for the cross-tier link "
                         "(quantize8/quantize16; overrides --fedat-bits)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    if args.codec:
        from repro.compress import transport
        try:
            args.fedat_bits = transport.cross_tier_bits(args.codec)
        except ValueError as e:
            ap.error(str(e))
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = smoke_shape("train") if args.smoke else SHAPES[args.shape]
    tcfg = TrainConfig(
        fedat_enabled=args.multi_pod, fedat_sync_every=args.fedat_sync_every,
        fedat_compress_bits=args.fedat_bits, total_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, seed=args.seed)

    if args.smoke:
        mesh = make_host_mesh(n_pods=2 if args.multi_pod else 1)
        multi_pod = args.multi_pod and "pod" in mesh.shape
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        multi_pod = args.multi_pod
    n_pods = mesh.shape.get("pod", 1)

    fns = build(cfg, tcfg, mesh, multi_pod)
    pipe = TokenPipeline(cfg, shape, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)

    with mesh, shd.use_mesh(mesh):
        step_fn = jax.jit(
            fns.train_step,
            in_shardings=(fns.state_shardings, fns.batch_shardings),
            out_shardings=(fns.state_shardings, None))
        state = jax.jit(fns.init_state,
                        out_shardings=fns.state_shardings)(
            jax.random.PRNGKey(args.seed))
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            state, start = ckpt.restore(state)
            log.info("resumed from step %d", start)

        def batches():
            step = start
            while True:
                b = pipe.batch(step)
                if multi_pod:
                    b = steps_mod.split_batch_for_pods(b, n_pods)
                yield jax.tree.map(
                    lambda x, s: jax.device_put(x, s),
                    b, dict(fns.batch_shardings) if isinstance(
                        fns.batch_shardings, dict) else fns.batch_shardings)
                step += 1

        losses = []

        def on_metrics(step, metrics):
            losses.append(float(metrics["loss"]))
            if step % 5 == 0 or step == args.steps:
                log.info("step %d loss %.4f", step, losses[-1])

        runner = GuardedRunner(step_fn, ckpt, ckpt_every=args.ckpt_every,
                               inject_failure_rate=args.inject_failure_rate,
                               seed=args.seed)
        t0 = time.time()
        state, end = runner.run(state, batches(), args.steps,
                                start_step=start, on_metrics=on_metrics)
        dt = time.time() - t0
        log.info("done: %d steps in %.1fs (%.3fs/step); runner stats %s",
                 end - start, dt, dt / max(end - start, 1), runner.stats)
        return losses


if __name__ == "__main__":
    main()
