import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each applicable cell (configs/shapes.py::applicable):

  * train_4k     -> train_step   (single-pod: sync step; multi-pod: the
                                  FedAT pods-as-tiers step with compressed
                                  cross-tier collectives)
  * prefill_32k  -> serve_prefill
  * decode_32k / long_500k -> serve_step (one token against a seq_len cache)

and records compiled.memory_analysis(), cost_analysis() and the per-device
collective byte volume parsed from the partitioned HLO into
experiments/dryrun_<mesh>.json — the inputs to benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both [--out experiments]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, applicable
from repro.configs.base import TrainConfig
from repro.configs import registry
from repro.core import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.runtime import sharding as shd
from repro.runtime.hlo import collective_bytes, count_collectives


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               fedat_bits: int = 8, overrides: Dict[str, Any] = None,
               rules_override: Dict[str, Any] = None):
    """Returns (lowered, meta) for one cell."""
    cfg = registry.get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape):
        return None, {"skipped": True}
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    key = jax.random.PRNGKey(0)

    # tiny-batch cells (long_500k: B=1) cannot shard batch over the data
    # axis: replicate batch dims, keep model-axis sharding.
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    rules = dict(rules_override or {})
    if shape.global_batch < dp:
        rules.update({"batch": None, "cache_batch": None})
    rules = rules or None

    with mesh, shd.use_mesh(mesh, rules):
        if shape.kind == "train":
            tcfg = TrainConfig(fedat_enabled=multi_pod,
                               fedat_compress_bits=fedat_bits)
            if multi_pod:
                fns = steps_mod.make_fedat_step(cfg, tcfg, mesh,
                                                param_dtype=jnp.bfloat16)
                n_pods = mesh.shape["pod"]
                batch = steps_mod.split_batch_for_pods(
                    lm.input_specs(cfg, shape), n_pods)
                batch = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), batch)
            else:
                fns = steps_mod.make_single_pod_step(
                    cfg, tcfg, mesh, param_dtype=jnp.bfloat16)
                batch = lm.input_specs(cfg, shape)
            state = jax.eval_shape(fns.init_state, key)
            lowered = jax.jit(
                fns.train_step,
                in_shardings=(fns.state_shardings, fns.batch_shardings),
                out_shardings=(fns.state_shardings, None),
                donate_argnums=(0,),  # state buffers reused in place
            ).lower(state, batch)
        else:
            params = lm.abstract_params(cfg, tp, jnp.bfloat16)
            p_sh = jax.tree.map(
                lambda a: shd.logical_sharding(a, mesh),
                lm.param_axes(cfg, tp),
                is_leaf=lambda l: isinstance(l, tuple))
            cache = lm.abstract_cache(cfg, shape.global_batch, shape.seq_len,
                                      tp)
            c_sh = jax.tree.map(
                lambda a: shd.logical_sharding(a, mesh),
                lm.cache_axes_tree(cfg, tp),
                is_leaf=lambda l: isinstance(l, tuple) and all(
                    x is None or isinstance(x, str) for x in l))
            if shape.kind == "prefill":
                batch = lm.input_specs(cfg, shape)
                b_sh = {k: shd.logical_sharding(a, mesh)
                        for k, a in lm.input_axes(cfg, shape).items()}
                fn = lambda p, b, c: lm.serve_prefill(
                    cfg, lm.anchor_params(cfg, p, tp), b, tp, c)
                lowered = jax.jit(
                    fn, in_shardings=(p_sh, b_sh, c_sh),
                    out_shardings=(None, c_sh),  # match in: enables aliasing
                    donate_argnums=(2,),  # cache updated in place
                ).lower(params, batch, cache)
            else:
                toks = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
                pos = jax.ShapeDtypeStruct((), jnp.int32)
                t_sh = shd.logical_sharding(("batch",), mesh)
                fn = lambda p, t, po, c: lm.serve_step(
                    cfg, lm.anchor_params(cfg, p, tp), t, po, tp, c)
                lowered = jax.jit(
                    fn, in_shardings=(p_sh, t_sh, None, c_sh),
                    out_shardings=(None, c_sh),  # match in: enables aliasing
                    donate_argnums=(3,),  # cache updated in place
                ).lower(params, toks, pos, cache)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "n_devices": mesh.size, "tp": tp}
    return lowered, meta


def compile_cell(arch: str, shape_name: str, multi_pod: bool,
                 fedat_bits: int = 8, overrides=None,
                 rules_override=None) -> Dict[str, Any]:
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod, fedat_bits,
                               overrides, rules_override)
    if lowered is None:
        return meta
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ma = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    meta.update({
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        },
        "peak_bytes_per_device": int(ma.argument_size_in_bytes +
                                     ma.temp_size_in_bytes),
        "cost": {"flops": float(cost.get("flops", 0.0)),
                 "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "collectives": count_collectives(txt),
        "collective_bytes_per_device": collective_bytes(txt),
    })
    print(f"[dryrun] {meta['arch']:22s} {meta['shape']:12s} "
          f"{meta['mesh']:6s} compile={meta['compile_s']:7.1f}s "
          f"peak/dev={meta['peak_bytes_per_device']/2**30:6.2f}GiB "
          f"coll/dev={meta['collective_bytes_per_device']/2**20:8.1f}MiB",
          flush=True)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments")
    ap.add_argument("--fedat-bits", type=int, default=8)
    ap.add_argument("--no-serve-fsdp", action="store_true",
                    help="replicate weights over the data axis for serve "
                         "cells (removes per-step weight gathers; only for "
                         "models whose weights fit — see §Perf cell B)")
    args = ap.parse_args()

    archs = registry.ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    failures = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                rules = None
                if args.no_serve_fsdp and SHAPES[shape].kind != "train":
                    rules = {"fsdp": None}
                try:
                    results.append(compile_cell(arch, shape, multi,
                                                args.fedat_bits,
                                                rules_override=rules))
                except Exception:
                    failures += 1
                    print(f"[dryrun] FAILED {arch} {shape} "
                          f"{'multi' if multi else 'single'}", flush=True)
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "multi" if multi else "single",
                                    "failed": True})
        tag = "multi" if multi else "single"
        with open(os.path.join(args.out, f"dryrun_{tag}.json"), "w") as f:
            json.dump([r for r in results
                       if r.get("mesh") == tag or r.get("skipped")], f,
                      indent=1)
    ok = sum(1 for r in results if "peak_bytes_per_device" in r)
    skip = sum(1 for r in results if r.get("skipped"))
    print(f"[dryrun] done: {ok} compiled, {skip} skipped (documented), "
          f"{failures} FAILED", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
