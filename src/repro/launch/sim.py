"""Simulation-experiment launcher: the declarative spec CLI under the
launch namespace (``launch/train.py`` drives the datacenter-scale trainer;
this drives the paper-scale FL simulation).

    PYTHONPATH=src python -m repro.launch.sim --set strategy.name=fedat \
        --sweep transport.codec=none,quantize8

Delegates to :mod:`repro.api.cli`; see that module for the flag grammar.
"""
from repro.api.cli import main

if __name__ == "__main__":
    main()
