"""Serving driver: thin shim over the serving plane (repro.serve).

``main`` drives :class:`repro.serve.engine.ServeEngine` — fixed-slot
continuous batching with per-slot positions, exact prompt handoff, and
cache-row reset on slot recycle — over any decoder arch in the registry
(KV-cache layouts full / sliding-window ring / SSM state / hybrid are
handled by lm.init_cache).

Example (CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
      --requests 8 --max-new 16

The original prototype :class:`Server` is kept below for API
compatibility; the engine supersedes it (the prototype shares one
position counter across slots, so a recycled slot continues at its
neighbours' RoPE offset — tolerable for throughput smoke tests, wrong
for parity: see DESIGN.md §Serving-plane).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from collections import deque
from typing import Deque, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.models import lm

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    #: True when the server's max_len cut generation short of max_new
    truncated: bool = False

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class Server:
    """Fixed-slot continuous-batching decoder (prototype; see module
    docstring — new code should use :class:`repro.serve.ServeEngine`)."""

    def __init__(self, cfg, batch_slots: int, max_len: int, tp: int = 1,
                 seed: int = 0, dtype=jnp.float32):
        self.cfg = cfg
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pending: List[Deque[int]] = [deque() for _ in range(batch_slots)]
        self.max_len = max_len
        self.tp = tp
        self.params = lm.init_params(cfg, jax.random.PRNGKey(seed), tp, dtype)
        self.cache = lm.init_cache(cfg, batch_slots, max_len, tp, dtype)
        self.pos = 0
        self._prefill = jax.jit(
            lambda p, b, c: lm.serve_prefill(cfg, p, b, tp, c))
        self._step = jax.jit(
            lambda p, t, po, c: lm.serve_step(cfg, p, t, po, tp, c))

    # -- batched service loop ------------------------------------------------
    def run(self, requests: List[Request]) -> Tuple[List[Request], int]:
        queue = list(requests)
        done: List[Request] = []
        B = len(self.slots)

        # pack first wave: right-align prompts to a common prefill length
        wave = [queue.pop(0) for _ in range(min(B, len(queue)))]
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt
            self.slots[i] = r
        logits, self.cache = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}, self.cache)
        self.pos = plen
        next_tok = np.asarray(
            jnp.argmax(logits[:, :self.cfg.vocab_size], -1), np.int32)

        steps = 0
        while any(s is not None for s in self.slots) and self.pos < \
                self.max_len:
            logits, self.cache = self._step(
                self.params, jnp.asarray(next_tok),
                jnp.asarray(self.pos, jnp.int32), self.cache)
            self.pos += 1
            steps += 1
            next_tok = np.array(
                jnp.argmax(logits[:, :self.cfg.vocab_size], -1), np.int32,
                copy=True)
            for i, r in enumerate(self.slots):
                if r is None:
                    continue
                if self.pending[i]:
                    # mid-handoff: this step consumed a prompt token, and
                    # more remain — feed the next one, emit nothing
                    next_tok[i] = self.pending[i].popleft()
                    continue
                r.out.append(int(next_tok[i]))
                if r.done:
                    done.append(r)
                    # continuous batching: hand the slot to a queued
                    # request; its *whole* prompt decodes token-by-token
                    # into the live batch via the pending queue
                    self.slots[i] = queue.pop(0) if queue else None
                    if self.slots[i] is not None:
                        pending = deque(
                            int(t) for t in self.slots[i].prompt)
                        next_tok[i] = pending.popleft()
                        self.pending[i] = pending
        for s in self.slots:
            if s is not None:
                s.truncated = True  # max_len fired before max_new tokens
                done.append(s)
        return done, steps


def main(argv=None):
    from repro.serve import ServeEngine, ServeRequest, ServeSpec, report

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.is_decoder:
        raise SystemExit(f"{args.arch} is encoder-only: nothing to decode")
    rng = np.random.default_rng(args.seed)
    reqs = [ServeRequest(i, rng.integers(0, cfg.vocab_size,
                                         rng.integers(4, args.prompt_len + 1)
                                         ).astype(np.int32),
                         args.max_new) for i in range(args.requests)]
    spec = ServeSpec(slots=args.slots,
                     max_len=args.prompt_len + args.max_new * 4,
                     prefill_len=args.prompt_len, max_new=args.max_new,
                     seed=args.seed)
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed), tp=1,
                            dtype=jnp.float32)
    engine = ServeEngine(cfg, params, spec)
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    r = report(done)
    log.info("served %d requests (%d truncated), %.1f tok/s, "
             "p50 latency %.3fs (traces: %s)",
             r["requests"], r["truncated"],
             sum(len(q.out) for q in done) / max(dt, 1e-9),
             r["latency_p50_s"], engine.trace_counts)
    return done


if __name__ == "__main__":
    main()
