"""Device-mesh construction + the named-mesh registry (DESIGN.md
§Scale-mapping).

Two families of meshes, one axis vocabulary (``pod``/``data``/``model``,
see :mod:`repro.runtime.sharding`):

* :func:`make_production_mesh` — the datacenter shapes: one pod of 256
  chips as ``(data=16, model=16)``, or two pods as ``(pod=2, data=16,
  model=16)`` where the ``pod`` axis is the FedAT *tier* axis.
* :func:`make_host_mesh` — a degenerate mesh over however many devices
  this host actually has, so CPU drivers/tests exercise the *same*
  sharded code path on 1–N local devices (force N with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before first
  jax init).

Both are functions (never module-level constants) so importing this
module does not touch jax device state.

The string grammar accepted by :func:`resolve_mesh` / :func:`parse_mesh_name`
is what :class:`~repro.api.spec.MeshSpec` serializes to — ``None`` (single
device, no mesh), ``"host"``, ``"host:<n_pods>"``, ``"production"``,
``"production:2"``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """``jax.make_mesh`` across jax versions: newer releases take an
    ``axis_types`` argument (all-Auto here, the GSPMD default); older ones
    reject the kwarg and default to the same behaviour."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The 256/512-chip datacenter mesh.

    ``multi_pod=False``: one pod, ``(data=16, model=16)`` — 256 devices.
    ``multi_pod=True``: two pods, ``(pod=2, data=16, model=16)`` — 512
    devices; the ``pod`` axis is the FedAT tier axis.

    Requires that many devices to be visible (the dry-run forces them via
    ``--xla_force_host_platform_device_count=512``).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_pods: int = 1) -> jax.sharding.Mesh:
    """A mesh over however many devices this host actually has.

    With ``n_pods == 1`` (or a device count not divisible by ``n_pods``)
    the shape is ``(data=n_devices, model=1)``; otherwise ``(pod=n_pods,
    data=n_devices/n_pods, model=1)``.  Used by CPU drivers and tests so a
    single code path covers 1 local device up to a forced N-device host.

    The indivisible-device-count fallback is a convenience for direct
    callers (``launch/train.py --multi_pod`` on a 1-device box); the
    declarative path (:func:`resolve_mesh`, i.e. ``MeshSpec``) rejects it
    instead — a spec that names ``host:N`` must get N pods or fail loudly.
    """
    n = len(jax.devices())
    if n_pods > 1 and n % n_pods == 0:
        return make_mesh((n_pods, n // n_pods, 1), ("pod", "data", "model"))
    return make_mesh((n, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# named meshes (the MeshSpec grammar)
# ---------------------------------------------------------------------------

MESH_KINDS = ("single", "host", "production")

#: data-axis sizes known without building the mesh (None = depends on the
#: runtime device count); MeshSpec uses this for static pad validation.
STATIC_DATA_AXIS = {"single": 1, "production": 16}


def parse_mesh_name(name: Optional[str]) -> Tuple[str, int]:
    """``None``/``"single"`` -> ("single", 1); ``"host[:p]"`` /
    ``"production[:p]"`` -> (kind, n_pods).  Raises ValueError with the
    accepted grammar on anything else."""
    if name is None or name == "single":
        return "single", 1
    kind, _, arg = str(name).partition(":")
    if kind not in ("host", "production"):
        raise ValueError(
            f"unknown mesh {name!r}; expected one of {MESH_KINDS} "
            f"(optionally 'host:<n_pods>' / 'production:2')")
    try:
        n_pods = int(arg) if arg else 1
    except ValueError:
        raise ValueError(f"bad n_pods in mesh name {name!r} "
                         f"(expected e.g. 'host:2')")
    if n_pods < 1:
        raise ValueError(f"mesh n_pods must be >= 1, got {n_pods}")
    if kind == "production" and n_pods > 2:
        raise ValueError(
            f"production mesh has 1 or 2 pods, got n_pods={n_pods}")
    return kind, n_pods


def resolve_mesh(name: Optional[str]) -> Optional[jax.sharding.Mesh]:
    """Materialize a named mesh (``None`` for the single-device default).

    This touches jax device state, so callers (``SimEnv``) resolve lazily
    at environment build time, never at import time.
    """
    kind, n_pods = parse_mesh_name(name)
    if kind == "single":
        return None
    if kind == "host":
        n = len(jax.devices())
        if n_pods > 1 and n % n_pods:
            raise ValueError(
                f"mesh {name!r} needs a device count divisible by "
                f"n_pods={n_pods}, but this host has {n} device(s); "
                f"force one with XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N")
        return make_host_mesh(n_pods)
    return make_production_mesh(multi_pod=n_pods > 1)


# TPU v5e hardware model for the roofline analysis (per chip)
V5E_PEAK_FLOPS = 197e12        # bf16 FLOP/s
V5E_HBM_BW = 819e9             # bytes/s
V5E_ICI_BW = 50e9              # bytes/s per link
