"""Production mesh definitions.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16) — the ``pod``
axis is the FedAT *tier* axis (DESIGN.md §Scale-mapping).

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(n_pods: int = 1) -> jax.sharding.Mesh:
    """Degenerate mesh over however many devices this host actually has —
    used by CPU drivers/tests so the same code path exercises sharding."""
    n = len(jax.devices())
    if n_pods > 1 and n % n_pods == 0:
        return jax.make_mesh(
            (n_pods, n // n_pods, 1), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware model for the roofline analysis (per chip)
V5E_PEAK_FLOPS = 197e12        # bf16 FLOP/s
V5E_HBM_BW = 819e9             # bytes/s
V5E_ICI_BW = 50e9              # bytes/s per link
