"""Jit'd public wrappers around the Pallas kernels.

Handles shape massaging (padding to tile multiples, flattening batch/head
dims), exposes ``interpret=`` for CPU validation, and provides ``use_ref``
fallbacks so the same call sites run on non-TPU backends.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as fa
from repro.kernels import polyline_codec as codec
from repro.kernels import ref
from repro.kernels import rwkv6_scan
from repro.kernels import ssd as ssd_mod


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    target = -(-n // mult) * mult
    if target == n:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(x, pad), n


# --- codec -------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def compress(x: jax.Array, bits: int = 8, interpret: bool = True):
    """x: any shape -> (q (nb,256) int, scale (nb,1) f32, orig size)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    tile = codec.BLOCK * codec.TILE_B
    flat, _ = _pad_to(flat, 0, tile)
    blocks = flat.reshape(-1, codec.BLOCK)
    q, scale = codec.compress_blocks(blocks, bits, interpret=interpret)
    return q, scale


@functools.partial(jax.jit, static_argnames=("shape", "interpret"))
def decompress(q, scale, shape: Tuple[int, ...], interpret: bool = True):
    blocks = codec.decompress_blocks(q, scale, interpret=interpret)
    n = 1
    for s in shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(shape)


# --- attention ---------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None, interpret: bool = True):
    """q: (B, S, H, hd); k/v: (B, T, KV, hd) with H % KV == 0 (GQA).
    Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    # expand KV heads to query heads, flatten (B, H) -> BH
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    qf, S0 = _pad_to(qf, 1, fa.BQ)
    kf, T0 = _pad_to(kf, 1, fa.BK)
    vf, _ = _pad_to(vf, 1, fa.BK)
    hd_pad = -(-hd // 128) * 128
    if hd_pad != hd:
        qf, _ = _pad_to(qf, 2, 128)
        kf, _ = _pad_to(kf, 2, 128)
        vf, _ = _pad_to(vf, 2, 128)
    out = fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                             interpret=interpret,
                             scale=1.0 / (hd ** 0.5), kv_len=T0)
    out = out[:, :S0, :hd]
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def blocked_attention(q, k, v, causal: bool = True,
                      window: Optional[int] = None, block: int = 64,
                      prefix_len: int = 0):
    """Flash-style streaming attention in pure jnp (any backend).

    Same (B, S, H, hd) / (B, T, KV, hd) GQA contract and mask semantics
    as :func:`flash_attention`, same O(block * T) working set: queries are
    processed in static blocks and each block only touches the K/V rows it
    can see — the causal upper bound clips at ``(i+1) * block`` and a
    sliding window clips the lower bound — so the (S, T) logits matrix
    never materializes and causal configs do ~half the FLOPs of the naive
    path.  The block loop is unrolled at trace time (shapes are static),
    which keeps one trace per config under jit/vmap.  This is the flash
    backend's fallback wherever the Pallas kernel can't run (CPU/GPU
    hosts, interpret-free tests) — and it is *fast* there, not a stub.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    C = min(block, S)
    n = -(-S // C)
    out_blocks = []
    for i in range(n):
        s0, s1 = i * C, min((i + 1) * C, S)
        hi = T
        if causal and not prefix_len:
            hi = min(s1, T)
        lo = 0
        if window is not None:
            lo = max(0, s0 + 1 - window)
        qi = q[:, s0:s1].astype(jnp.float32) * scale   # (B, c, H, hd)
        qi = qi.reshape(B, s1 - s0, KV, G, hd)         # kv-major grouping
        ki = k[:, lo:hi].astype(jnp.float32)           # (B, t, KV, hd)
        vi = v[:, lo:hi].astype(jnp.float32)
        logits = jnp.einsum("bckgd,btkd->bckgt", qi, ki)
        # masks are static (numpy, never staged): all-visible blocks skip
        # the where() entirely, so the common causal interior is mask-free
        qpos = np.arange(s0, s1)[:, None]
        kpos = np.arange(lo, hi)[None, :]
        mask = None
        if causal:
            m = qpos >= kpos
            if prefix_len:
                m = m | (kpos < prefix_len)
            mask = m
        if window is not None:
            m = (qpos - kpos) < window
            mask = m if mask is None else (mask & m)
        if mask is not None and not mask.all():
            logits = jnp.where(jnp.asarray(mask)[None, :, None, None, :],
                               logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bckgt,btkd->bckgd", probs, vi)
        out_blocks.append(o.reshape(B, s1 - s0, H, hd))
    out = out_blocks[0] if n == 1 else jnp.concatenate(out_blocks, axis=1)
    return out.astype(q.dtype)


def default_attention_impl() -> str:
    """The flash-attention implementation ``attention(impl="auto")``
    resolves to on this backend: the compiled Pallas kernel on TPU, the
    blocked jnp path everywhere else (interpret-mode Pallas is a
    correctness vehicle, never a perf default)."""
    return "pallas" if jax.default_backend() == "tpu" else "blocked"


def attention(q, k, v, causal: bool = True, window: Optional[int] = None,
              impl: str = "auto", block: int = 64, prefix_len: int = 0):
    """One entry point for fast attention: q (B, S, H, hd); k/v
    (B, T, KV, hd) with H % KV == 0.  ``impl`` is ``auto`` (backend
    availability, :func:`default_attention_impl`) | ``pallas`` |
    ``pallas_interpret`` | ``blocked``."""
    if impl == "auto":
        impl = default_attention_impl()
    if impl in ("pallas", "pallas_interpret"):
        if prefix_len:
            raise NotImplementedError(
                "prefix-LM masks need impl='blocked' (the Pallas kernel "
                "only knows causal/window masks)")
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=(impl == "pallas_interpret"))
    if impl == "blocked":
        return blocked_attention(q, k, v, causal=causal, window=window,
                                 block=block, prefix_len=prefix_len)
    raise ValueError(f"unknown attention impl {impl!r}; expected "
                     f"auto | pallas | pallas_interpret | blocked")


# --- wkv6 ---------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, chunk: int = 64, interpret: bool = True):
    """r/k/v/logw: (BH, S, N); u: (BH, N) -> y (BH, S, N)."""
    S = r.shape[1]
    rp, _ = _pad_to(r, 1, chunk)
    kp, _ = _pad_to(k, 1, chunk)      # k = 0 on padding: no state effect
    vp, _ = _pad_to(v, 1, chunk)
    lp, _ = _pad_to(logw, 1, chunk)   # logw = 0: decay 1 on padding
    y = rwkv6_scan.wkv6(rp, kp, vp, lp, u, chunk=chunk, interpret=interpret)
    return y[:, :S]


# --- ssd ----------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, Bm, Cm, da, chunk: int = 64, interpret: bool = True):
    """x: (BH, S, P); Bm/Cm: (BH, S, N); da: (BH, S, 1) -> y (BH, S, P)."""
    S = x.shape[1]
    xp, _ = _pad_to(x, 1, chunk)
    bp, _ = _pad_to(Bm, 1, chunk)
    cp, _ = _pad_to(Cm, 1, chunk)
    dp, _ = _pad_to(da, 1, chunk)
    y = ssd_mod.ssd_scan(xp, bp, cp, dp, chunk=chunk, interpret=interpret)
    return y[:, :S]
