# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Kernel layer: Pallas TPU kernels + jnp fallbacks behind jit'd wrappers.

The stable public surface is re-exported here — call sites outside this
package (models/attention.py, compress/transport.py, benchmarks) import
from ``repro.kernels``, not the implementation modules:

  * :func:`attention` — one entry point for fast attention (``impl=``
    auto | pallas | pallas_interpret | blocked), with
    :func:`flash_attention` (the Pallas kernel wrapper),
    :func:`blocked_attention` (the streaming jnp path), and
    :func:`default_attention_impl` (what ``auto`` resolves to here).
  * :func:`compress` / :func:`decompress` — the polyline codec's blocked
    quantizer (compress/transport.py rides these).
  * :func:`wkv6` / :func:`ssd` — the RWKV-6 and Mamba-2 chunked scans.
  * :mod:`ref` — the naive jnp oracles every kernel is tested against.
"""
from repro.kernels import ref
from repro.kernels.ops import (
    attention,
    blocked_attention,
    compress,
    decompress,
    default_attention_impl,
    flash_attention,
    ssd,
    wkv6,
)

__all__ = [
    "attention",
    "blocked_attention",
    "compress",
    "decompress",
    "default_attention_impl",
    "flash_attention",
    "ref",
    "ssd",
    "wkv6",
]
