"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Deliberately naive implementations: full logits matrices, token-level
recurrent scans — slow but obviously correct.  tests/test_kernels.py sweeps
shapes and dtypes asserting kernel ~= oracle in interpret mode.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# --- codec -----------------------------------------------------------------

def compress_blocks(x: jax.Array, bits: int = 8):
    qmax = (1 << (bits - 1)) - 1
    dtype = jnp.int8 if bits <= 8 else jnp.int16
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1,
                                keepdims=True) / qmax, 1e-30)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax,
                 qmax).astype(dtype)
    return q, scale.astype(jnp.float32)


def decompress_blocks(q: jax.Array, scale: jax.Array, out_dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(out_dtype)


# --- attention ---------------------------------------------------------------

def attention(q, k, v, causal=True, window=None):
    """q: (BH, S, hd); k/v: (BH, T, hd) -> (BH, S, hd). Full materialized."""
    S, T = q.shape[1], k.shape[1]
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bst,bth->bsh", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


# --- wkv6 -------------------------------------------------------------------

def wkv6(r, k, v, logw, u):
    """Token-level recurrence (the definitional form). All: (BH, S, N)."""
    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    lw = logw.astype(jnp.float32)
    u32 = u.astype(jnp.float32)
    BH, S, N = r.shape

    def step(state, xs):
        rt, kt, vt, lwt = xs
        kv = kt[:, :, None] * vt[:, None, :]              # (BH, N, N)
        y = jnp.einsum("bi,bij->bj", rt,
                       state + u32[:, :, None] * kv)
        state = jnp.exp(lwt)[:, :, None] * state + kv
        return state, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r32, k32, v32, lw))
    _, ys = jax.lax.scan(step, jnp.zeros((BH, N, N), jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)


# --- ssd --------------------------------------------------------------------

def ssd(x, Bm, Cm, da):
    """Token-level SSD recurrence. x: (BH,S,P); Bm/Cm: (BH,S,N);
    da: (BH,S,1)."""
    x32 = x.astype(jnp.float32)
    B32, C32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    da32 = da.astype(jnp.float32)
    BH, S, P = x.shape
    N = Bm.shape[-1]

    def step(h, xs):
        xt, bt, ct, dat = xs
        h = jnp.exp(dat)[..., None] * h + \
            jnp.einsum("bp,bn->bpn", xt, bt)
        y = jnp.einsum("bn,bpn->bp", ct, h)
        return h, y

    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(B32, 1, 0),
          jnp.moveaxis(C32, 1, 0), jnp.moveaxis(da32[..., 0], 1, 0)[..., None])
    h0 = jnp.zeros((BH, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
