"""Pallas TPU kernel for the blockwise quantization codec (the TPU-native
polyline-encoding analogue, DESIGN.md §Hardware-adaptation).

compress:   x (n, 256) f32/bf16 -> q (n, 256) int8|int16, scale (n, 1) f32
decompress: inverse.

Tiling: TILE_B logical 256-blocks per grid step -> VMEM tiles of
(TILE_B, 256).  256 = 2 TPU lanes x 128; the per-block max reduction runs
on the VPU along the lane dim, the scale broadcast hits the MXU-free path.
This is the hot loop of FedAT's cross-tier sync (quantize -> pod collective
-> dequantize), so keeping it bandwidth-bound at ~1 byte out per 4 bytes in
is the design goal (see benchmarks/kernel_bench.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256     # codec block (matches compress/quantize.py)
TILE_B = 8      # codec blocks per grid step -> (8, 256) VMEM tiles


def _qmax(bits: int) -> int:
    return (1 << (bits - 1)) - 1


def _compress_kernel(x_ref, q_ref, s_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)                     # (TILE_B, 256)
    qmax = float(_qmax(bits))
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    q_ref[...] = q.astype(q_ref.dtype)
    s_ref[...] = scale.astype(jnp.float32)


def _decompress_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)                     # (TILE_B, 1)
    x_ref[...] = (q * s).astype(x_ref.dtype)


def compress_blocks(x: jax.Array, bits: int = 8, interpret: bool = False):
    """x: (n_blocks, 256) -> (q (n_blocks, 256) int, scale (n_blocks, 1))."""
    n = x.shape[0]
    assert x.shape[1] == BLOCK and n % TILE_B == 0, x.shape
    dtype = jnp.int8 if bits <= 8 else jnp.int16
    grid = (n // TILE_B,)
    return pl.pallas_call(
        functools.partial(_compress_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_B, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((TILE_B, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((TILE_B, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, BLOCK), dtype),
                   jax.ShapeDtypeStruct((n, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def decompress_blocks(q: jax.Array, scale: jax.Array, out_dtype=jnp.float32,
                      interpret: bool = False) -> jax.Array:
    n = q.shape[0]
    assert q.shape[1] == BLOCK and n % TILE_B == 0, q.shape
    grid = (n // TILE_B,)
    return pl.pallas_call(
        _decompress_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_B, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((TILE_B, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_B, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, BLOCK), out_dtype),
        interpret=interpret,
    )(q, scale)
