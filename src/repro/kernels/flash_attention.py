"""Flash attention (causal / sliding-window) Pallas TPU kernel.

Grid: (B*H, S/BQ) — one (BQ, hd) query tile per step, online-softmax over
K/V tiles of BK rows held in VMEM.  Running max/sum/accumulator live in
VMEM scratch; K/V stream through a fori_loop with dynamic in-tile slices,
so VMEM holds O(BQ*hd + BK*hd) regardless of sequence length.  Causal and
window masks are applied per (BQ, BK) tile with absolute-position iota; for
sliding-window configs the K loop is *clipped* to the live window slab
(O(S*W) work instead of O(S^2) — the h2o-danube SWA path).

MXU alignment: BQ = BK = 128, head_dim padded to a lane multiple by the
caller (ops.flash_attention handles padding/unpadding).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BK = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, window,
                 seq_len: int, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale              # (BQ, hd)
    T = k_ref.shape[1]
    q_pos = qi * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)

    # K-range this query tile can see (causal upper bound, window lower)
    hi = T if not causal else jnp.minimum((qi + 1) * BQ, T)
    lo = 0
    if window is not None:
        lo = jnp.maximum(qi * BQ + 1 - window, 0)
    lo_blk = (lo // BK) if window is not None else 0
    hi_blk = pl.cdiv(hi, BK)

    def body(kb, carry):
        acc, m, l = carry
        # NB: a bare int in the pl.load index tuple breaks the interpret-mode
        # discharge rule on jax 0.4.x — use a length-1 dslice and squeeze.
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(kb * BK, BK),
                            slice(None)))[0].astype(jnp.float32)   # (BK, hd)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(kb * BK, BK),
                            slice(None)))[0].astype(jnp.float32)
        s = q @ k.T                                        # (BQ, BK)
        k_pos = kb * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
        mask = k_pos < seq_len
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    hd = q.shape[-1]
    acc0 = jnp.zeros((BQ, hd), jnp.float32)
    m0 = jnp.full((BQ,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((BQ,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(lo_blk, hi_blk, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window=None,
                    interpret: bool = False, scale: float = None,
                    kv_len: int = None) -> jax.Array:
    """q: (BH, S, hd); k/v: (BH, T, hd). hd and S should be 128-aligned
    (ops.py pads); returns (BH, S, hd).

    ``scale``/``kv_len`` override the softmax scale and the true (unpadded)
    KV length when the caller padded hd or T.
    """
    BH, S, hd = q.shape
    T = k.shape[1]
    assert S % BQ == 0 and T % BK == 0, (S, T)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kv_len = kv_len if kv_len is not None else T
    grid = (BH, S // BQ)
    return pl.pallas_call(
        functools.partial(_attn_kernel, causal=causal, window=window,
                          seq_len=kv_len, scale=scale),
        grid=grid,
        in_specs=[pl.BlockSpec((1, BQ, hd), lambda b, i: (b, i, 0)),
                  pl.BlockSpec((1, T, hd), lambda b, i: (b, 0, 0)),
                  pl.BlockSpec((1, T, hd), lambda b, i: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, BQ, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
