"""Mamba2 SSD chunked-scan Pallas TPU kernel (the Zamba2 backbone hot loop).

Same carry-state-in-VMEM pattern as rwkv6_scan: grid (B*H, S/C) sequential
over chunks, (P, N) f32 state in scratch.  Per chunk:

    y  = (C_t . h) * exp(cum_t)  +  (C_t.B_s masked-decay kernel) @ x
    h' = exp(cum_C) h + sum_s exp(cum_C - cum_s) x_s (x) B_s

A is scalar per head (Mamba2), so the decay matrix is (C, C) — cheaper than
WKV6's per-channel (C, C, N) tensor.  dt is pre-folded into x by the caller
(ops.ssd_scan), matching models/mamba2.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, da_ref, y_ref, state):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0].astype(jnp.float32)                      # (C, P)
    Bm = b_ref[0].astype(jnp.float32)                     # (C, N)
    Cm = c_ref[0].astype(jnp.float32)                     # (C, N)
    da = da_ref[0].astype(jnp.float32)                    # (C, 1) log decay
    h = state[...]                                        # (P, N)

    cum = jnp.cumsum(da[:, 0])                            # (C,)
    # cross-chunk
    y = jnp.exp(cum)[:, None] * (Cm @ h.T)                # (C, P)
    # intra-chunk: G[t,s] = C_t.B_s ; L[t,s] = exp(cum_t - cum_s) (s <= t)
    C = x.shape[0]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    mask = s_idx <= t_idx
    g = Cm @ Bm.T
    ldec = jnp.where(mask, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    y = y + (g * ldec) @ x
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    kdec = jnp.exp(cum[-1] - cum)[:, None] * Bm           # (C, N)
    state[...] = jnp.exp(cum[-1]) * h + x.T @ kdec


def ssd_scan(x, Bm, Cm, da, chunk: int = 64, interpret: bool = False):
    """x: (BH, S, P); Bm/Cm: (BH, S, N); da: (BH, S, 1) log decay <= 0.
    Returns y (BH, S, P).  S must divide by ``chunk`` (ops.py pads)."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    grid = (BH, S // chunk)
    xspec = pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0))
    nspec = pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0))
    dspec = pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0))
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[xspec, nspec, nspec, dspec],
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, Bm, Cm, da)
