"""WKV6 chunked-scan Pallas TPU kernel (RWKV-6 time-mix recurrence).

Grid: (B*H, S/C) with the chunk dim iterated innermost (sequentially on
TPU), carrying the (N, N) f32 state in VMEM scratch across chunk steps —
the TPU idiom for linear-RNN scans: intra-chunk work is two (C, N) x (N, N)
MXU matmuls plus a (C, C) masked decay kernel, and only the O(N^2) state
crosses chunk boundaries (never written back to HBM between chunks).

The intra-chunk decay matrix is exponentiated in *pairwise* log space
(diff <= 0 before exp — the same stability trick as the jnp reference in
models/rwkv6.py; a factorized exp overflows for strong decays).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, state,
                *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    r = r_ref[0].astype(jnp.float32)                      # (C, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)                    # log decay <= 0
    u = u_ref[0].astype(jnp.float32)                      # (1, N) bonus
    S0 = state[...]

    cum = jnp.cumsum(lw, axis=0)
    cum_prev = cum - lw
    # cross-chunk + intra-chunk (s < t) + diagonal bonus
    rdec = r * jnp.exp(cum_prev)
    y = rdec @ S0                                         # (C, N_v)
    C = r.shape[0]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    mask = s_idx < t_idx
    diff = cum_prev[:, None, :] - cum[None, :, :]         # (t, s, N)
    diff = jnp.where(mask[:, :, None], diff, -jnp.inf)
    att = jnp.einsum("ti,si,tsi->ts", r, k, jnp.exp(diff))
    diag = jnp.sum(r * k * u, axis=1)
    y = y + att @ v + diag[:, None] * v
    y_ref[0] = y.astype(y_ref.dtype)

    dtot = jnp.exp(cum[-1:, :])                           # (1, N)
    kdec = k * jnp.exp(cum[-1:, :] - cum)
    state[...] = dtot.T * S0 + kdec.T @ v


def wkv6(r, k, v, logw, u, chunk: int = 64, interpret: bool = False):
    """r/k/v/logw: (BH, S, N); u: (BH, N). Returns y (BH, S, N).

    S must be a multiple of ``chunk`` (ops.py pads).
    """
    BH, S, N = r.shape
    assert S % chunk == 0, (S, chunk)
    grid = (BH, S // chunk)
    spec = pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0))
    return pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, N), lambda b, c: (b, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((BH, S, N), r.dtype),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
