# Tier-1 verification + smoke benchmarks (CPU, Pallas interpret mode).
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)
export JAX_PLATFORMS ?= cpu

.PHONY: test test-kernels test-faultplane test-serve test-population \
	test-topology bench-smoke bench-engine bench-roofline bench-serve \
	smoke-example smoke-lm smoke-fault smoke-serve smoke-population \
	smoke-topology docs check-docs

test:
	$(PY) -m pytest -x -q

# the kernel layer as a required job of its own: the Pallas kernels in
# interpret mode against their jnp oracles + the attention-backend knob
# (flash vs reference through the model and federated layers)
test-kernels:
	$(PY) -m pytest -q tests/test_kernels.py tests/test_attention_backend.py

# the fault plane as a required job of its own: churn/blackout/gate
# units + the bitwise crash-resume suite (including the SIGKILL chaos
# subprocess test)
test-faultplane:
	$(PY) -m pytest -q tests/test_faultplane.py tests/test_crash_resume.py

# the serving plane as a required job of its own: prefill/decode bitwise
# parity vs the training forward, continuous-batching conservation +
# slot recycling, and spec-hash-addressed checkpoint loading
test-serve:
	$(PY) -m pytest -q tests/test_serve.py

# the population plane as a required job of its own: stacked-vs-streaming
# bitwise parity, the FLGo-style availability/responsiveness/completion
# process grammars, flat-memory scaling, and the cross-plane composition
# suites live in tests/test_population.py
test-population:
	$(PY) -m pytest -q tests/test_population.py

# the topology plane as a required job of its own: the degenerate
# bitwise contract (1-silo/1-edge zero-delay topology == flat FedAT),
# per-link delay/codec/byte accounting, delayed-gradient compensation,
# and the topology x faults x population cross-plane suites
test-topology:
	$(PY) -m pytest -q tests/test_topology.py

# regenerate the introspected ExperimentSpec reference (docs/SPEC.md)
docs:
	$(PY) scripts/gen_spec_docs.py

# CI docs gate: docs/SPEC.md must match the dataclasses (no drift) and
# every intra-repo markdown link must resolve
check-docs:
	$(PY) scripts/gen_spec_docs.py --check
	$(PY) scripts/check_links.py

# spec-API quickstart as an executable smoke test (CI runs this)
smoke-example:
	$(PY) examples/quickstart.py --updates 12

# 2-round federated tiny_lm through the CLI: exercises the model
# registry path (data.model) end-to-end on every push (CI runs this)
smoke-lm:
	$(PY) -m repro.api.cli \
	    --set data.model=tiny_lm --set data.n_clients=8 \
	    --set data.samples_per_client=12 --set tiers.n_tiers=2 \
	    --set tiers.clients_per_round=2 --set tiers.n_unstable=0 \
	    --set engine.local_epochs=1 --set engine.total_updates=2 \
	    --set engine.eval_every=2

# 2-round run under the full fault plane through the CLI: client churn,
# a poisoned uplink behind the validation gate, and a tier blackout
# (CI runs this on every push)
smoke-fault:
	$(PY) -m repro.api.cli \
	    --set data.n_clients=8 --set data.samples_per_client=12 \
	    --set data.image_hw=8 --set tiers.n_tiers=2 \
	    --set tiers.clients_per_round=2 --set tiers.n_unstable=0 \
	    --set engine.local_epochs=1 --set engine.total_updates=2 \
	    --set engine.eval_every=2 \
	    --set faults.churn_rate=0.5 --set 'faults.churn_window=[1,40]' \
	    --set faults.churn_downtime=10 --set faults.nan_rate=0.5 \
	    --set faults.blackouts=1 --set 'faults.blackout_window=[1,20]' \
	    --set faults.blackout_duration=10

# train -> checkpoint -> serve through the CLI: 2 federated tiny_lm
# rounds with --checkpoint-dir, then the `serve` subcommand resolves the
# directory by spec hash and decodes a Poisson request stream (CI runs
# this on every push)
smoke-serve:
	rm -rf /tmp/smoke_serve_ckpt
	$(PY) -m repro.api.cli \
	    --set data.model=tiny_lm --set data.n_clients=8 \
	    --set data.samples_per_client=12 --set tiers.n_tiers=2 \
	    --set tiers.clients_per_round=2 --set tiers.n_unstable=0 \
	    --set engine.local_epochs=1 --set engine.total_updates=2 \
	    --set engine.eval_every=2 --checkpoint-dir /tmp/smoke_serve_ckpt
	$(PY) -m repro.api.cli serve --resume-from /tmp/smoke_serve_ckpt \
	    --requests 6 --slots 3 --prompt-len 12 --max-new 6 --rate 25

# 2 federated rounds over a 100k-client population through the CLI:
# streaming plane, stochastic availability, flat device memory — proves
# the population spec section end-to-end on every push (CI runs this)
smoke-population:
	$(PY) -m repro.api.cli \
	    --set data.n_clients=100000 --set data.samples_per_client=12 \
	    --set data.image_hw=8 --set tiers.n_tiers=5 \
	    --set tiers.clients_per_round=8 --set tiers.n_unstable=1000 \
	    --set engine.local_epochs=1 --set engine.total_updates=2 \
	    --set engine.eval_every=2 \
	    --set population.plane=streaming \
	    --set population.availability=bernoulli:0.9:20 \
	    --set population.eval_clients=32

# 2-region hierarchical federation through the CLI: 2 silos x 2 edges,
# WAN delay bands on every link class, a lossy silo->global WAN codec,
# and delayed-gradient compensation on the stale silo path (CI runs
# this on every push)
smoke-topology:
	$(PY) -m repro.api.cli \
	    --set data.n_clients=16 --set data.samples_per_client=12 \
	    --set data.image_hw=8 --set tiers.n_tiers=1 \
	    --set tiers.clients_per_round=4 --set tiers.n_unstable=0 \
	    --set engine.local_epochs=1 --set engine.total_updates=4 \
	    --set engine.eval_every=2 \
	    --set topology.n_silos=2 --set topology.edges_per_silo=2 \
	    --set 'topology.delay.client_edge=[0.5,1.5]' \
	    --set 'topology.delay.edge_silo=[1,3]' \
	    --set 'topology.delay.silo_global=[2,6]' \
	    --set topology.codec.silo_global=quantize8 \
	    --set topology.compensation=0.5 --set topology.silo_skew=0.5

bench-smoke:
	$(PY) -m benchmarks.run codec codec_e2e kernels

# kernel roofline: per-kernel achieved FLOP/s vs the machine roof
# (calibrated in place off-TPU), merged into BENCH_engine.json next to
# the engine rows.  SMOKE=1 shrinks sizes/reps (the CI push workflow
# runs `make bench-roofline SMOKE=1`).
bench-roofline:
	$(PY) -m benchmarks.run roofline $(if $(SMOKE),--smoke) \
	    --json BENCH_engine.json

# engine hot-path throughput (events/sec per strategy) + the scale axis
# (512-client scenario single-device and client-sharded on a forced
# multi-device host mesh, subprocess) + the federated-LM path
# (tiny_lm with/without the polyline codec) + the fault-plane
# degradation curve (0/5%/20% fault pressure) + the population plane
# (streaming rounds at 1k/100k/1M clients, flat-memory pin) + the
# topology plane (flat vs hierarchical ev/s, per-link-class wire bytes,
# compensation vs staleness, degenerate bitwise pin re-checked) +
# machine-readable JSON for cross-PR perf tracking
bench-engine:
	$(PY) -m benchmarks.run engine engine_scaled engine_lm \
	    engine_faults engine_sharded engine_population \
	    engine_topology $(if $(SMOKE),--smoke) --json BENCH_engine.json

# serving-plane latency under open-loop Poisson load, from spec-hash-
# verified federated checkpoints (train -> checkpoint -> load -> serve):
# p50/p95/p99 latency + TTFT + tok/s per load level into
# BENCH_serve.json.  SMOKE=1 shrinks rounds/requests (the CI push
# workflow runs `make bench-serve SMOKE=1`).
bench-serve:
	$(PY) -m benchmarks.serve_bench $(if $(SMOKE),--smoke) \
	    --json BENCH_serve.json
