# Tier-1 verification + smoke benchmarks (CPU, Pallas interpret mode).
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)
export JAX_PLATFORMS ?= cpu

.PHONY: test bench-smoke bench-engine smoke-example docs check-docs

test:
	$(PY) -m pytest -x -q

# regenerate the introspected ExperimentSpec reference (docs/SPEC.md)
docs:
	$(PY) scripts/gen_spec_docs.py

# CI docs gate: docs/SPEC.md must match the dataclasses (no drift) and
# every intra-repo markdown link must resolve
check-docs:
	$(PY) scripts/gen_spec_docs.py --check
	$(PY) scripts/check_links.py

# spec-API quickstart as an executable smoke test (CI runs this)
smoke-example:
	$(PY) examples/quickstart.py --updates 12

# codec + codec_e2e only: the attention/scan kernel benches hit a known
# jax-version incompatibility in interpret mode (see test_kernels skips)
bench-smoke:
	$(PY) -m benchmarks.run codec codec_e2e

# engine hot-path throughput (events/sec per strategy) + the scale axis:
# the 512-client scaled scenario single-device and client-sharded on a
# forced multi-device host mesh (subprocess) + machine-readable JSON for
# cross-PR perf tracking
bench-engine:
	$(PY) -m benchmarks.run engine engine_scaled engine_sharded \
	    --json BENCH_engine.json
