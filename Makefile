# Tier-1 verification + smoke benchmarks (CPU, Pallas interpret mode).
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)
export JAX_PLATFORMS ?= cpu

.PHONY: test bench-smoke bench-engine smoke-example

test:
	$(PY) -m pytest -x -q

# spec-API quickstart as an executable smoke test (CI runs this)
smoke-example:
	$(PY) examples/quickstart.py --updates 12

# codec + codec_e2e only: the attention/scan kernel benches hit a known
# jax-version incompatibility in interpret mode (see test_kernels skips)
bench-smoke:
	$(PY) -m benchmarks.run codec codec_e2e

# engine hot-path throughput (events/sec per strategy) + machine-readable
# JSON for cross-PR perf tracking
bench-engine:
	$(PY) -m benchmarks.run engine --json BENCH_engine.json
